//! End-to-end driver (the repository's headline validation run).
//!
//! Reproduces the paper's Figures 3 and 4 protocol on the full stack:
//! synthetic MNIST-like digits (or real MNIST if `data/mnist/` exists) →
//! 1-vs-1 tasks (2v3 easy, 3v8 hard) → Full / Attentive / Budgeted
//! Pegasos, 10-run averages → learning curves, average features, and
//! early-stopped prediction errors — AND routes the held-out margin
//! evaluation through the AOT-compiled XLA artifact when `artifacts/` is
//! built, proving the three layers compose.
//!
//! Run: `cargo run --release --example mnist_attentive`
//! Outputs: fig3.csv, fig4.csv + the console tables recorded in
//! EXPERIMENTS.md.

use attentive::config::{DataConfig, ExperimentConfig};
use attentive::coordinator::scheduler::{run_experiment, SweepOutcome};
use attentive::margin::policy::CoordinatePolicy;
use attentive::metrics::export::{curves_to_csv, Table};
use attentive::runtime::margin_exec::{shapes, BlockedMarginExecutor};
use attentive::runtime::Runtime;
use attentive::stst::boundary::AnyBoundary;

fn experiment(name: &str, pair: (i64, i64), boundary: AnyBoundary, policy: CoordinatePolicy) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        data: DataConfig::Synth { seed: 7, count: 20_000 },
        pair,
        boundary,
        policy,
        lambda: 1e-4,
        epochs: 5,
        runs: 10,
        eval_every: 400,
        ..ExperimentConfig::paper_default()
    }
}

fn figure(pair: (i64, i64), label: &str) -> (Vec<SweepOutcome>, f64, f64) {
    let policy = CoordinatePolicy::WeightSampled;
    println!("=== {label}: digits {} vs {} (10 runs each) ===", pair.0, pair.1);

    let att = run_experiment(&experiment(
        &format!("{label}-attentive"),
        pair,
        AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy,
    ))
    .expect("attentive run");
    // Paper protocol: budgeted gets attentive's measured average budget.
    let k = att.avg_features.round().max(1.0) as usize;
    let bud = run_experiment(&experiment(
        &format!("{label}-budgeted(k={k})"),
        pair,
        AnyBoundary::Budgeted { k },
        CoordinatePolicy::Permuted, // sorting is impossible for budgeted
    ))
    .expect("budgeted run");
    let full = run_experiment(&experiment(
        &format!("{label}-full"),
        pair,
        AnyBoundary::Full,
        policy,
    ))
    .expect("full run");

    let mut t = Table::new(&[
        "algorithm",
        "avg feats (train)",
        "speedup",
        "test err (full eval)",
        "test err (early-stop)",
        "pred feats",
    ]);
    for out in [&att, &bud, &full] {
        t.row(&[
            out.name.clone(),
            format!("{:.1}", out.avg_features),
            format!("{:.1}x", out.speedup(784)),
            format!("{:.4}", out.final_test_error),
            format!("{:.4}", out.final_test_error_early),
            format!("{:.1}", out.predict_avg_features),
        ]);
    }
    println!("{}", t.render());
    let att_feats = att.avg_features;
    let att_pred_feats = att.predict_avg_features;
    (vec![att, bud, full], att_feats, att_pred_feats)
}

fn main() {
    // Figure 3: the easy pair (2 vs 3). Paper: ~49 features, ~15x.
    let (fig3, feats3, pred3) = figure((2, 3), "fig3");
    // Figure 4: the hard pair — paper's "3 vs 10" caption, digits (3, 8)
    // here (see DESIGN.md §7). Paper: ~72 features.
    let (fig4, feats4, pred4) = figure((3, 8), "fig4");

    println!(
        "hard pair needs more attention than easy pair — prediction feats: {pred4:.1} (3v8) vs {pred3:.1} (2v3) [{}]; train feats: {feats4:.1} vs {feats3:.1}",
        if pred4 > pred3 { "matches the paper's 72-vs-49 ordering" } else { "MISMATCH vs paper" }
    );

    for (name, outs) in [("fig3.csv", &fig3), ("fig4.csv", &fig4)] {
        let mut curves = Vec::new();
        for o in outs.iter() {
            curves.push(o.mean_features.clone());
            curves.push(o.mean_test_error.clone());
        }
        curves_to_csv(&curves, std::path::Path::new(name)).expect("csv");
        println!("curves written to {name}");
    }

    // ---- Three-layer composition check: run one margin batch through
    // the AOT XLA artifact and cross-check against the native evaluator.
    match Runtime::cpu() {
        Ok(rt) if rt.artifact_available(&BlockedMarginExecutor::artifact_name()) => {
            let exec = BlockedMarginExecutor::new(&rt).expect("compile artifact");
            let mut gen = attentive::data::synth::SynthDigits::new(3);
            let imgs: Vec<Vec<f64>> = (0..8).map(|i| gen.render(if i % 2 == 0 { 2 } else { 3 })).collect();
            let refs: Vec<&[f64]> = imgs.iter().map(|v| v.as_slice()).collect();
            let ys: Vec<f64> = (0..8).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
            let mut rng = attentive::util::rng::Rng64::seed_from_u64(9);
            let w: Vec<f64> = (0..shapes::DIM).map(|_| rng.range_f64(-0.05, 0.05)).collect();
            let rows = exec.prefixes(&w, &refs, &ys).expect("xla margins");
            let mut max_gap = 0.0f64;
            for (row, (x, &y)) in rows.iter().zip(imgs.iter().zip(&ys)) {
                let mut s = 0.0;
                for (k, cell) in row.iter().enumerate() {
                    for j in k * shapes::BLOCK..(k + 1) * shapes::BLOCK {
                        s += w[j] * x[j];
                    }
                    max_gap = max_gap.max((cell - y * s).abs());
                }
            }
            println!(
                "XLA artifact vs native prefix margins: max |gap| = {max_gap:.2e} over {} cells ({} platform)",
                rows.len() * shapes::NBLOCKS,
                rt.platform()
            );
        }
        _ => println!("artifacts/ not built — skipping the XLA composition check (run `make artifacts`)"),
    }
}
