//! Figure 2 end-to-end: validate the Constant STST boundary against
//! simulation — (a) empirical decision-error rates vs the Brownian-bridge
//! closed form across (n, δ); (b) expected stopping time vs the O(√n) law
//! and the Wald bound. Writes `fig2.csv` next to the binary's CWD.
//!
//! Run: `cargo run --release --example boundary_sim`

use attentive::metrics::curve::Curve;
use attentive::metrics::export::{curves_to_csv, Table};
use attentive::sim::bridge::{simulate_decision_errors, BridgeSimConfig};
use attentive::sim::stopping::{fit_sqrt, simulate_stopping_times, StoppingSimConfig};
use attentive::stst::brownian;

fn main() {
    // ---- Figure 2(a): decision errors track theory --------------------
    let cfg = BridgeSimConfig { walks_per_cell: 30_000, ..Default::default() };
    let ns = [256usize, 1024, 4096];
    let deltas = [0.01, 0.05, 0.1, 0.2, 0.3];
    let pts = simulate_decision_errors(&cfg, &ns, &deltas);

    let mut t = Table::new(&["n", "target δ", "empirical", "ratio", "stop rate", "E[T|stop]"]);
    for p in &pts {
        t.row(&[
            p.n.to_string(),
            format!("{:.3}", p.delta),
            format!("{:.4}", p.empirical),
            format!("{:.2}", p.empirical / p.delta),
            format!("{:.3}", p.stop_rate),
            format!("{:.1}", p.mean_stop_time),
        ]);
    }
    println!("Figure 2(a) — Constant STST decision errors vs Brownian-bridge theory");
    println!("{}", t.render());

    // ---- Figure 2(b): stopping time is O(sqrt(n)) ---------------------
    let scfg = StoppingSimConfig { walks_per_n: 20_000, ..Default::default() };
    let ns2 = [64usize, 128, 256, 512, 1024, 2048, 4096];
    let spts = simulate_stopping_times(&scfg, &ns2);
    let (c, r2) = fit_sqrt(&spts);

    let mut t2 = Table::new(&["n", "mean stop", "c·sqrt(n) fit", "wald bound", "crossed"]);
    for p in &spts {
        t2.row(&[
            p.n.to_string(),
            format!("{:.1}", p.mean_stop),
            format!("{:.1}", c * (p.n as f64).sqrt()),
            format!("{:.1}", p.wald_bound),
            format!("{:.1}%", p.crossed_frac * 100.0),
        ]);
    }
    println!("Figure 2(b) — mean stopping time: fit E[T] ≈ {c:.2}·sqrt(n), R² = {r2:.4}");
    println!("{}", t2.render());

    // Closed-form sanity row: the boundary inverts its crossing probability.
    let tau = brownian::constant_boundary_level(0.1, 0.0, 100.0);
    println!(
        "sanity: τ(δ=0.1, var=100) = {:.3}; P(cross) = {:.4} (target 0.1)",
        tau,
        brownian::bridge_crossing_prob(tau, 0.0, 100.0)
    );

    // ---- CSV export ----------------------------------------------------
    let mut err_curves: Vec<Curve> = Vec::new();
    for &n in &ns {
        let mut cv = Curve::new(format!("fig2a/n{n}/empirical-vs-delta"));
        for p in pts.iter().filter(|p| p.n == n) {
            cv.push(p.delta, p.empirical);
        }
        err_curves.push(cv);
    }
    let mut stop_curve = Curve::new("fig2b/mean-stop-vs-n");
    let mut bound_curve = Curve::new("fig2b/wald-bound-vs-n");
    for p in &spts {
        stop_curve.push(p.n as f64, p.mean_stop);
        bound_curve.push(p.n as f64, p.wald_bound);
    }
    err_curves.push(stop_curve);
    err_curves.push(bound_curve);
    let path = std::path::Path::new("fig2.csv");
    curves_to_csv(&err_curves, path).expect("write csv");
    println!("series written to {}", path.display());
}
