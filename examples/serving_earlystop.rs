//! Serving with stochastic focus of attention: train a model, snapshot
//! it, and serve a mixed easy/hard request stream through the threaded
//! prediction service — demonstrating that per-request cost tracks input
//! difficulty, and comparing against the dense XLA predict artifact.
//!
//! Run: `cargo run --release --example serving_earlystop`

use std::time::Instant;

use attentive::coordinator::service::{ModelSnapshot, PredictionService};
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::synth::{SynthDigits, SynthConfig};
use attentive::data::task::BinaryTask;
use attentive::learner::attentive::attentive_pegasos;
use attentive::margin::policy::CoordinatePolicy;
use attentive::stst::boundary::AnyBoundary;

fn main() {
    // ---- Train + snapshot ---------------------------------------------
    let ds = SynthDigits::new(7).generate_classes(6_000, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).expect("task");
    let mut learner = attentive_pegasos(task.dim(), 1e-4, 0.1);
    Trainer::new(TrainerConfig { epochs: 4, eval_every: 0, curves: false, ..Default::default() })
        .fit(&mut learner, &task);
    let snapshot = ModelSnapshot::from_trained(
        &mut learner,
        AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        // Permuted, not Sequential: raw pixel order is spatially
        // correlated (whole rows push the sum one way), violating the
        // exchangeability the Brownian-bridge boundary assumes — the
        // reason the paper randomizes coordinate order.
        CoordinatePolicy::Permuted,
    );
    let weights = snapshot.weights.clone();

    // ---- Traffic: clean digits (easy) vs heavily-noised ones (hard) ----
    let make_noisy = SynthConfig {
        pixel_noise: 0.35,
        salt_prob: 0.2,
        jitter_px: 4.0,
        ..Default::default()
    };
    let mut clean_gen = SynthDigits::new(100);
    let mut noisy_gen = SynthDigits::with_config(101, make_noisy);
    let requests: Vec<(Vec<f64>, bool)> = (0..4_000)
        .map(|i| {
            let digit = if i % 2 == 0 { 2u8 } else { 3u8 };
            if i % 4 < 2 {
                (clean_gen.render(digit), false)
            } else {
                (noisy_gen.render(digit), true)
            }
        })
        .collect();

    // ---- Serve ----------------------------------------------------------
    let (handle, run) = PredictionService::new(snapshot, 16, 1024, 0).with_workers(4).spawn();
    let t0 = Instant::now();
    let mut clean_feats = 0usize;
    let mut noisy_feats = 0usize;
    let (mut clean_n, mut noisy_n) = (0usize, 0usize);
    std::thread::scope(|scope| {
        let mut pending = Vec::new();
        for chunk in requests.chunks(500) {
            let handle = handle.clone();
            pending.push(scope.spawn(move || {
                let mut out = Vec::new();
                for (x, hard) in chunk {
                    let r = handle.score(x.clone()).expect("service alive");
                    out.push((*hard, r.features_evaluated));
                }
                out
            }));
        }
        for p in pending {
            for (hard, feats) in p.join().unwrap() {
                if hard {
                    noisy_feats += feats;
                    noisy_n += 1;
                } else {
                    clean_feats += feats;
                    clean_n += 1;
                }
            }
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let stats = run.stats.snapshot();
    drop(handle);
    run.join();

    println!("served {} requests in {:.3}s  ({:.0} req/s, {} batches)", stats.served, dt, stats.served as f64 / dt, stats.batches);
    println!(
        "attention at work: clean requests {:.1} feats/pred, noisy requests {:.1} feats/pred (of 784)",
        clean_feats as f64 / clean_n.max(1) as f64,
        noisy_feats as f64 / noisy_n.max(1) as f64,
    );
    println!("overall avg features/prediction: {:.1} (full evaluation would be 784)", stats.avg_features());

    // ---- Cross-check against the dense XLA predict artifact ------------
    xla_cross_check(&weights, &requests);
}

/// Compare the native dot product against the dense XLA predict artifact
/// (requires the `pjrt` feature and a vendored xla crate).
#[cfg(feature = "pjrt")]
fn xla_cross_check(weights: &[f64], requests: &[(Vec<f64>, bool)]) {
    use attentive::runtime::predict_exec::DensePredictExecutor;
    use attentive::runtime::Runtime;
    match Runtime::cpu() {
        Ok(rt) if rt.artifact_available(&DensePredictExecutor::artifact_name()) => {
            let exec = DensePredictExecutor::new(&rt).expect("artifact");
            let sample: Vec<&(Vec<f64>, bool)> = requests.iter().take(64).collect();
            let mut flat = Vec::new();
            for (x, _) in &sample {
                flat.extend_from_slice(x);
            }
            let t1 = Instant::now();
            let margins = exec.margins(weights, &flat, sample.len()).expect("margins");
            let xla_dt = t1.elapsed();
            let mut max_gap = 0.0f64;
            for ((x, _), m) in sample.iter().zip(&margins) {
                max_gap = max_gap.max((attentive::margin::dot(weights, x) - m).abs());
            }
            println!(
                "dense XLA predict artifact: {} margins in {:?}, max |gap| vs native dot = {max_gap:.2e}",
                margins.len(),
                xla_dt
            );
        }
        _ => println!("artifacts/ not built — skipping XLA predict cross-check"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn xla_cross_check(_weights: &[f64], _requests: &[(Vec<f64>, bool)]) {
    println!("built without the `pjrt` feature — skipping XLA predict cross-check");
}
