//! Quickstart: train Attentive Pegasos on a synthetic 2-vs-3 digit task
//! and print the headline numbers (features/example, speedup, accuracy).
//!
//! Run: `cargo run --release --example quickstart`

use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::synth::SynthDigits;
use attentive::data::task::BinaryTask;
use attentive::learner::attentive::attentive_pegasos;
use attentive::learner::pegasos::{Pegasos, PegasosConfig};
use attentive::learner::OnlineLearner;

fn main() {
    // 1. Data: deterministic synthetic MNIST-like digits, classes 2 and 3.
    let ds = SynthDigits::new(7).generate_classes(4_000, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).expect("task");
    let (train, test) = task.split(0.8);
    println!(
        "task {}: {} train / {} test examples, {} features",
        task.name(),
        train.len(),
        test.len(),
        train.dim()
    );

    // 2. Learners: full Pegasos vs Attentive Pegasos (Constant STST, δ=0.1).
    let trainer = Trainer::new(TrainerConfig {
        epochs: 5,
        eval_every: 0,
        curves: false,
        ..Default::default()
    });

    let mut full =
        Pegasos::full(train.dim(), PegasosConfig { lambda: 1e-4, ..Default::default() });
    let rf = trainer.fit_eval(&mut full, &train, Some(&test));

    let mut att = attentive_pegasos(train.dim(), 1e-4, 0.1);
    let ra = trainer.fit_eval(&mut att, &train, Some(&test));

    // 3. The paper's headline comparison.
    println!("\n                      features/example   test error   early-stop predict");
    println!(
        "full pegasos          {:>10.1}          {:>8.4}       (always {} feats)",
        rf.avg_features_per_example(),
        rf.final_test_error,
        train.dim()
    );
    println!(
        "attentive pegasos     {:>10.1}          {:>8.4}       err {:.4} @ {:.1} feats",
        ra.avg_features_per_example(),
        ra.final_test_error,
        ra.final_test_error_early,
        ra.predict_avg_features
    );
    println!(
        "\ntraining speedup: {:.1}x fewer feature evaluations; prediction: {:.1}x",
        train.dim() as f64 / ra.avg_features_per_example(),
        train.dim() as f64 / ra.predict_avg_features.max(1.0)
    );
    println!("learner: {}", att.name());
}
