//! Runtime shard churn, end-to-end over real sockets and **both** I/O
//! backends: protocol v5 `add-model` / `remove-model` cycling live
//! shards while sibling score *and* learn traffic streams uninterrupted
//! — zero sheds, zero routing errors, no stale routes. Also the
//! remove-while-learning ordering (trainer quiesced before the hub
//! drains), the full error-path matrix (duplicate / unknown / default /
//! trainer-less learn adds), lifecycle-state visibility through the
//! `models` and `stats` ops, and the loadgen churn sidecar.

use std::time::{Duration, Instant};

use attentive::config::{IoBackend, ServerConfig, TrainerWireConfig};
use attentive::coordinator::service::{Features, ModelSnapshot, ServingModel};
use attentive::margin::policy::CoordinatePolicy;
use attentive::server::loadgen::{self, Client, ClientMode, LoadGenConfig};
use attentive::server::protocol::{Request, Response};
use attentive::server::tcp::TcpServer;
use attentive::stst::boundary::AnyBoundary;

const DIM: usize = 784;

/// Flat binary snapshot: deterministic score sign on inky inputs.
fn flat_snapshot(w: f64) -> ModelSnapshot {
    ModelSnapshot {
        weights: vec![w; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    }
}

/// The backends this platform can run (the event loop needs epoll).
fn backends() -> Vec<IoBackend> {
    let mut all = vec![IoBackend::Threads];
    if cfg!(target_os = "linux") {
        all.push(IoBackend::EventLoop);
    }
    all
}

/// Deterministic wire-trainer knobs: queue outsizes every stream in
/// this file, publish cadence is count-only.
fn trainer_cfg() -> TrainerWireConfig {
    TrainerWireConfig {
        queue: 4096,
        publish_every_updates: 8,
        publish_every_ms: 0,
        lambda: 1e-2,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
        seed: 23,
        ..Default::default()
    }
}

fn server_on(backend: IoBackend, trainer: Option<TrainerWireConfig>) -> TcpServer {
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        io_backend: backend,
        event_threads: 2,
        workers: 2,
        queue: 4096,
        trainer,
        ..Default::default()
    };
    TcpServer::serve_models(
        &cfg,
        vec![
            ("default".into(), flat_snapshot(1.0).into()),
            ("sibling".into(), flat_snapshot(-1.0).into()),
        ],
    )
    .expect("bind loopback churn server")
}

/// Wait until the background reclaim finishes and `name` vanishes from
/// the `models` table; any interim listing must carry a non-`serving`
/// lifecycle state (the shard was unrouted synchronously).
fn wait_drained(client: &mut Client, name: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let entries = client.models().expect("models op during drain");
        match entries.iter().find(|e| e.name == name) {
            None => return,
            Some(e) => assert_ne!(
                e.state, "serving",
                "removed shard {name:?} must never be listed as serving"
            ),
        }
        assert!(Instant::now() < deadline, "shard {name:?} never finished draining");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The acceptance scenario: add → score + learn → remove, three cycles,
/// while sibling score and learn traffic streams through the same port.
/// Zero sheds, zero errors, no stale routes — on either backend.
#[test]
fn churn_cycles_never_disturb_streaming_siblings() {
    for backend in backends() {
        let server = server_on(backend, Some(trainer_cfg()));
        let addr = server.local_addr().to_string();

        // Background sibling A: loadgen scoring the default shard.
        let load_addr = addr.clone();
        let load = std::thread::spawn(move || {
            loadgen::run(&LoadGenConfig {
                addr: load_addr,
                connections: 3,
                requests: 600,
                pipeline: 4,
                hard_fraction: 0.5,
                mode: ClientMode::V2Binary,
                seed: 7,
                ..Default::default()
            })
            .expect("sibling loadgen")
        });

        // Background sibling B: a learn stream on the default shard.
        let learn_addr = addr.clone();
        let learner = std::thread::spawn(move || {
            let mut client = Client::connect(&learn_addr).expect("learn connect");
            for i in 0..200u32 {
                let x = Features::Sparse {
                    idx: vec![i % 64, 64 + (i % 32)],
                    val: vec![1.0, -0.5],
                };
                let y = if i % 2 == 0 { 1 } else { -1 };
                match client.learn(None, y, x).expect("sibling learn answered") {
                    Response::Learned { .. } => {}
                    other => panic!("sibling learn must never error, got {other:?}"),
                }
            }
        });

        // Foreground: churn throwaway learn-enabled shards.
        let mut control = Client::connect(&addr).expect("control connect");
        assert_eq!(control.negotiate().unwrap(), 7, "backend {backend:?}: v7 grant");
        for cycle in 0..3 {
            let name = format!("live-{cycle}");
            let (id, dim) = control
                .add_model(&name, &flat_snapshot(1.0).into(), true)
                .expect("add-model");
            assert_eq!(dim, DIM);
            assert!(id >= 2, "backend {backend:?}: runtime ids follow the boot shards");

            // The new shard serves and learns immediately.
            match control.score_model(&name, vec![0.5; DIM]).unwrap() {
                Response::Score { score, .. } => assert!(score > 0.0, "backend {backend:?}"),
                other => panic!("{backend:?}: expected score, got {other:?}"),
            }
            match control
                .learn(Some(&name), -1, Features::Sparse { idx: vec![3], val: vec![1.0] })
                .unwrap()
            {
                Response::Learned { seen, .. } => assert!(seen >= 1),
                other => panic!("{backend:?}: expected learn ack, got {other:?}"),
            }
            // Binary wire routes by the freshly interned id too.
            match control.score_sparse2(id, vec![9], vec![1.0], 0).unwrap() {
                Response::Score { score, .. } => assert!(score > 0.0),
                other => panic!("{backend:?}: expected binary score, got {other:?}"),
            }

            // Visible in the registry tables with a trainer attached.
            let entry = control
                .models()
                .unwrap()
                .into_iter()
                .find(|e| e.name == name)
                .expect("added shard listed");
            assert_eq!(entry.state, "serving");
            let report = control
                .stats()
                .unwrap()
                .models
                .into_iter()
                .find(|m| m.name == name)
                .expect("added shard in stats");
            assert!(report.trainer, "backend {backend:?}: trainer attached on add");

            control.remove_model(&name).expect("remove-model");
            // Routes die synchronously: by name on the JSON wire ...
            match control.score_model(&name, vec![0.5; DIM]).unwrap() {
                Response::Error { retryable, .. } => assert!(!retryable),
                other => panic!("{backend:?}: removed name must unroute, got {other:?}"),
            }
            // ... and by the (never reissued) id on the binary wire.
            match control.score_sparse2(id, vec![9], vec![1.0], 0).unwrap() {
                Response::Error { error, retryable, .. } => {
                    assert!(error.contains("unknown model"), "got {error:?}");
                    assert!(!retryable);
                }
                other => panic!("{backend:?}: stale id must unroute, got {other:?}"),
            }
            wait_drained(&mut control, &name);
        }

        // Siblings never noticed: every request answered, nothing shed.
        let report = load.join().unwrap();
        assert_eq!(report.answered, report.sent, "backend {backend:?}: all answered");
        assert_eq!(report.errors, 0, "backend {backend:?}: zero sibling errors");
        assert_eq!(report.overloaded, 0, "backend {backend:?}: zero sibling sheds");
        learner.join().unwrap();

        let stats = control.stats().unwrap();
        assert_eq!(stats.overloaded, 0, "backend {backend:?}");
        assert_eq!(stats.protocol_errors, 0, "backend {backend:?}");
        // The boot shards still route; the churned names are gone.
        let names: Vec<String> =
            control.models().unwrap().into_iter().map(|e| e.name).collect();
        assert!(names.iter().any(|n| n == "default"));
        assert!(names.iter().any(|n| n == "sibling"));
        assert!(!names.iter().any(|n| n.starts_with("live-")), "no stale entries: {names:?}");
        server.shutdown();
    }
}

/// Remove-while-learning: the trainer is quiesced (queue drained, final
/// snapshot published, thread joined) before the hub drains, so a hot
/// learn stream into the dying shard loses no ack and never crashes the
/// server — in-flight examples either ack or answer a structured
/// retryable error, never a dropped connection.
#[test]
fn remove_mid_learn_stream_quiesces_trainer_then_drains() {
    for backend in backends() {
        let server = server_on(backend, Some(trainer_cfg()));
        let addr = server.local_addr().to_string();
        let mut control = Client::connect(&addr).expect("control connect");
        control.negotiate().unwrap();
        control.add_model("hot", &flat_snapshot(0.0).into(), true).expect("add-model");

        // A learn stream hammering the shard from another connection.
        let learn_addr = addr.clone();
        let feeder = std::thread::spawn(move || {
            let mut client = Client::connect(&learn_addr).expect("feeder connect");
            let (mut acked, mut refused) = (0u64, 0u64);
            for i in 0..400u32 {
                let x = Features::Sparse { idx: vec![i % 128], val: vec![1.0] };
                let y = if i % 2 == 0 { 1 } else { -1 };
                // The connection must survive the removal: every send is
                // answered, either with an ack or a structured error.
                match client.learn(Some("hot"), y, x).expect("feeder stays connected") {
                    Response::Learned { .. } => acked += 1,
                    Response::Error { .. } => refused += 1,
                    other => panic!("unexpected learn reply {other:?}"),
                }
            }
            (acked, refused)
        });

        // Wait until the trainer has provably accepted work, then yank
        // the shard out from under the stream.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let fed = control
                .stats()
                .unwrap()
                .models
                .into_iter()
                .find(|m| m.name == "hot")
                .is_some_and(|m| m.learn_examples >= 1);
            if fed {
                break;
            }
            assert!(Instant::now() < deadline, "feeder never reached the trainer");
            std::thread::sleep(Duration::from_millis(5));
        }
        control.remove_model("hot").expect("remove-model mid-stream");
        wait_drained(&mut control, "hot");

        let (acked, refused) = feeder.join().unwrap();
        assert_eq!(acked + refused, 400, "every feeder send answered");
        // The shard was live when the stream started, so some examples
        // landed before the unroute.
        assert!(acked >= 1, "pre-removal examples ack ({acked} acked, {refused} refused)");

        // The server is unharmed: siblings still score and learn.
        match control.score(vec![0.5; DIM]).unwrap() {
            Response::Score { score, .. } => assert!(score > 0.0, "backend {backend:?}"),
            other => panic!("{backend:?}: expected score, got {other:?}"),
        }
        assert!(matches!(
            control
                .learn(None, 1, Features::Sparse { idx: vec![1], val: vec![1.0] })
                .unwrap(),
            Response::Learned { .. }
        ));
        server.shutdown();
    }
}

/// The error matrix over the wire: duplicate adds, trainer-less learn
/// adds, unknown / default removals — each a structured, correctly
/// classified error that leaves the connection open.
#[test]
fn add_and_remove_error_paths_are_structured_and_classified() {
    // No trainer config: learn-enabled adds must be refused outright.
    let server = server_on(IoBackend::Threads, None);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");
    client.negotiate().unwrap();
    let snapshot: ServingModel = flat_snapshot(1.0).into();

    client.add_model("dup", &snapshot, false).expect("first add");
    // Duplicate name: MODEL_EXISTS, non-retryable.
    match client
        .call(&Request::AddModel { name: "dup".into(), snapshot: snapshot.clone(), learn: false })
        .unwrap()
    {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("already exists"), "got {error:?}");
            assert!(!retryable, "a duplicate name never resolves by retrying");
        }
        other => panic!("expected model-exists, got {other:?}"),
    }
    // Learn-enabled add on a server started without --learn knobs.
    match client
        .call(&Request::AddModel { name: "tr".into(), snapshot: snapshot.clone(), learn: true })
        .unwrap()
    {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("no trainer configured"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected trainer refusal, got {other:?}"),
    }
    // Unknown removal.
    match client.call(&Request::RemoveModel { name: "ghost".into() }).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("unknown model"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected unknown-model, got {other:?}"),
    }
    // The default shard is the v1 compatibility anchor: DEFAULT_MODEL.
    match client.call(&Request::RemoveModel { name: "default".into() }).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("default shard"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected default-model refusal, got {other:?}"),
    }
    // Empty names are malformed, not a routing miss.
    match client
        .call(&Request::AddModel { name: String::new(), snapshot: snapshot.clone(), learn: false })
    {
        Err(_) => {} // parse-level rejection is fine too
        Ok(Response::Error { retryable, .. }) => assert!(!retryable),
        Ok(other) => panic!("expected invalid-name error, got {other:?}"),
    }

    // None of that closed the connection, and the working add survived.
    let names: Vec<String> = client.models().unwrap().into_iter().map(|e| e.name).collect();
    assert!(names.iter().any(|n| n == "dup"));
    client.remove_model("dup").expect("cleanup remove");
    let stats = server.shutdown();
    assert_eq!(stats.overloaded, 0);
}

/// The loadgen churn sidecar: `--churn N` drives N add → score → remove
/// cycles on throwaway shards alongside the main pass and reports them.
#[test]
fn loadgen_churn_sidecar_reports_cycles() {
    let server = server_on(IoBackend::Threads, None);
    let addr = server.local_addr().to_string();
    let report = loadgen::run(&LoadGenConfig {
        addr,
        connections: 2,
        requests: 200,
        pipeline: 4,
        hard_fraction: 0.3,
        seed: 11,
        churn_cycles: 3,
        ..Default::default()
    })
    .expect("loadgen with churn sidecar");
    assert_eq!(report.churned, 3, "every churn cycle completed");
    assert_eq!(report.errors, 0, "churn ops and main traffic all clean");
    assert_eq!(report.overloaded, 0);
    assert!(report.answered >= 200, "main pass plus churn probes all answered");
    server.shutdown();
}
