//! Integration: rust runtime ⇄ AOT artifacts.
//!
//! These tests need `make artifacts` to have run; they skip (with a loud
//! message) when `artifacts/` is absent so `cargo test` stays green on a
//! fresh checkout. They verify the *numerics* of the XLA path against the
//! native rust implementations — the cross-layer contract of the whole
//! three-layer design.

use attentive::data::synth::SynthDigits;
use attentive::margin::evaluator::BlockedEvaluator;
use attentive::runtime::margin_exec::{shapes, BlockedMarginExecutor};
use attentive::runtime::pegasos_exec::PegasosStepExecutor;
use attentive::runtime::predict_exec::DensePredictExecutor;
use attentive::runtime::Runtime;
use attentive::stst::boundary::ConstantBoundary;
use attentive::util::rng::Rng64;

fn runtime_or_skip() -> Option<Runtime> {
    let rt = Runtime::cpu().expect("PJRT CPU client must open");
    if !rt.artifact_available(&BlockedMarginExecutor::artifact_name()) {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(rt)
}

fn toy_weights(dim: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::seed_from_u64(seed);
    (0..dim).map(|_| rng.range_f64(-0.1, 0.1)).collect()
}

#[test]
fn margin_artifact_matches_native_prefixes() {
    let Some(rt) = runtime_or_skip() else { return };
    let exec = BlockedMarginExecutor::new(&rt).unwrap();
    let w = toy_weights(shapes::DIM, 1);
    let mut gen = SynthDigits::new(5);
    let imgs: Vec<Vec<f64>> = (0..4).map(|i| gen.render((i % 10) as u8)).collect();
    let refs: Vec<&[f64]> = imgs.iter().map(|v| v.as_slice()).collect();
    let ys = [1.0, -1.0, 1.0, -1.0];

    let rows = exec.prefixes(&w, &refs, &ys).unwrap();
    assert_eq!(rows.len(), 4);
    for (row, (x, &y)) in rows.iter().zip(imgs.iter().zip(ys.iter())) {
        assert_eq!(row.len(), shapes::NBLOCKS);
        // Native prefix computation (sequential order).
        let mut s = 0.0;
        let mut native = Vec::new();
        for k in 0..shapes::NBLOCKS {
            for j in k * shapes::BLOCK..(k + 1) * shapes::BLOCK {
                s += w[j] * x[j];
            }
            native.push(y * s);
        }
        for (a, b) in row.iter().zip(&native) {
            assert!((a - b).abs() < 1e-4, "xla {a} vs native {b}");
        }
    }
}

#[test]
fn margin_artifact_decisions_match_blocked_evaluator() {
    let Some(rt) = runtime_or_skip() else { return };
    let exec = BlockedMarginExecutor::new(&rt).unwrap();
    let w = toy_weights(shapes::DIM, 2);
    let mut gen = SynthDigits::new(6);
    let imgs: Vec<Vec<f64>> = (0..8).map(|i| gen.render((i % 10) as u8)).collect();
    let refs: Vec<&[f64]> = imgs.iter().map(|v| v.as_slice()).collect();
    let ys = vec![1.0; 8];
    let vars = vec![0.05; 8];
    let boundary = ConstantBoundary::new(0.1);

    let decisions = exec.decide(&w, &refs, &ys, 1.0, &vars, &boundary).unwrap();
    let native = BlockedEvaluator::new(shapes::BLOCK);
    let order: Vec<usize> = (0..shapes::DIM).collect();
    for (i, (charged, stopped, margin)) in decisions.iter().enumerate() {
        let nres = native.evaluate(&w, &imgs[i], ys[i], &order, 1.0, vars[i], &boundary);
        assert_eq!(*charged, nres.evaluated, "example {i} charged features");
        assert_eq!(
            *stopped,
            nres.outcome == attentive::margin::walker::WalkOutcome::EarlyStopped,
            "example {i} stop decision"
        );
        assert!((margin - nres.partial_margin).abs() < 1e-4, "example {i} margin");
    }
}

#[test]
fn pegasos_artifact_matches_reference_step() {
    let Some(rt) = runtime_or_skip() else { return };
    let exec = PegasosStepExecutor::new(&rt).unwrap();
    let w = toy_weights(shapes::DIM, 3);
    let x = toy_weights(shapes::DIM, 4);
    for (y, t, lambda) in [(1.0, 1, 1e-2), (-1.0, 7, 1e-4), (1.0, 1000, 0.5)] {
        let got = exec.step(&w, &x, y, t, lambda).unwrap();
        let want = PegasosStepExecutor::step_reference(&w, &x, y, t, lambda);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "t={t} lambda={lambda}: {a} vs {b}");
        }
    }
}

#[test]
fn predict_artifact_matches_dot() {
    let Some(rt) = runtime_or_skip() else { return };
    let exec = DensePredictExecutor::new(&rt).unwrap();
    let w = toy_weights(shapes::DIM, 5);
    // 70 examples: exercises the chunking across the 32-row batch.
    let mut gen = SynthDigits::new(7);
    let mut features = Vec::new();
    let mut expect = Vec::new();
    for i in 0..70 {
        let img = gen.render((i % 10) as u8);
        expect.push(attentive::margin::dot(&w, &img));
        features.extend_from_slice(&img);
    }
    let got = exec.margins(&w, &features, 70).unwrap();
    assert_eq!(got.len(), 70);
    for (a, b) in got.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = runtime_or_skip() else { return };
    let a = rt.load(&BlockedMarginExecutor::artifact_name()).unwrap();
    let b = rt.load(&BlockedMarginExecutor::artifact_name()).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b), "second load must hit the cache");
}

#[test]
fn manifest_geometry_matches_rust_constants() {
    let Some(rt) = runtime_or_skip() else { return };
    let path = rt.artifact_path("manifest.json");
    let text = std::fs::read_to_string(path).unwrap();
    let doc = attentive::util::json::Json::parse(&text).unwrap();
    assert_eq!(doc.get("dim").unwrap().as_usize(), Some(shapes::DIM));
    assert_eq!(doc.get("batch").unwrap().as_usize(), Some(shapes::BATCH));
    assert_eq!(doc.get("block").unwrap().as_usize(), Some(shapes::BLOCK));
}
