//! Integration: train → snapshot → serve, across thread boundaries and a
//! JSON round-trip to disk — the serving deployment path end-to-end.

use attentive::coordinator::service::{ModelSnapshot, PredictionService};
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::synth::SynthDigits;
use attentive::data::task::BinaryTask;
use attentive::learner::attentive::attentive_pegasos;
use attentive::learner::OnlineLearner;
use attentive::margin::policy::CoordinatePolicy;
use attentive::stst::boundary::AnyBoundary;
use attentive::util::json::Json;

fn train_snapshot() -> ModelSnapshot {
    let ds = SynthDigits::new(17).generate_classes(1_200, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).unwrap();
    let mut learner = attentive_pegasos(task.dim(), 1e-2, 0.1);
    Trainer::new(TrainerConfig { epochs: 2, eval_every: 0, curves: false, ..Default::default() })
        .fit(&mut learner, &task);
    let weights = learner.weights().to_vec();
    let var = {
        let vc = learner.var_cache_mut();
        let a = vc.var_sn(1.0, &weights);
        let b = vc.var_sn(-1.0, &weights);
        a.max(b)
    };
    ModelSnapshot {
        weights,
        var_sn: var,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        // Permuted, not Sequential: raw pixel order is spatially
        // correlated (whole rows push the sum one way), violating the
        // exchangeability the Brownian-bridge boundary assumes — the
        // reason the paper randomizes coordinate order.
        policy: CoordinatePolicy::Permuted,
    }
}

#[test]
fn train_snapshot_serve_round_trip() {
    let snapshot = train_snapshot();

    // Persist and reload the snapshot (deployment hand-off).
    let dir = attentive::util::tempdir::TempDir::new("svc");
    let path = dir.path().join("model.json");
    std::fs::write(&path, snapshot.to_json().to_string_pretty()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let reloaded = ModelSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(reloaded.weights, snapshot.weights);

    // Serve digit traffic from the reloaded snapshot.
    let (handle, run) = PredictionService::new(reloaded, 8, 128, 0).with_workers(2).spawn();
    let mut gen = SynthDigits::new(18);
    let mut correct = 0;
    let mut feats = 0usize;
    let total = 200;
    for i in 0..total {
        let digit = if i % 2 == 0 { 2u8 } else { 3u8 };
        let y = if digit == 2 { 1.0 } else { -1.0 };
        let resp = handle.score(gen.render(digit)).expect("service up");
        if y * resp.score > 0.0 {
            correct += 1;
        }
        feats += resp.features_evaluated;
    }
    let stats = run.stats.snapshot();
    drop(handle);
    run.join();

    assert_eq!(stats.served, total as u64);
    let acc = correct as f64 / total as f64;
    assert!(acc > 0.9, "serving accuracy {acc} too low");
    let avg = feats as f64 / total as f64;
    assert!(avg < 784.0 * 0.8, "early exit should save features (avg {avg})");
}

#[test]
fn service_survives_handle_clones_and_drops() {
    let snapshot = train_snapshot();
    let (handle, run) = PredictionService::new(snapshot, 4, 32, 1).spawn();
    let h2 = handle.clone();
    drop(handle); // one handle remains
    let mut gen = SynthDigits::new(19);
    let r = h2.score(gen.render(2)).expect("still alive via clone");
    assert!(r.features_evaluated > 0);
    drop(h2); // last handle gone -> workers exit
    run.join();
}

#[test]
fn full_boundary_service_always_evaluates_everything() {
    let mut snapshot = train_snapshot();
    snapshot.boundary = AnyBoundary::Full;
    let (handle, run) = PredictionService::new(snapshot, 4, 32, 2).spawn();
    let mut gen = SynthDigits::new(20);
    for d in [2u8, 3u8] {
        let r = handle.score(gen.render(d)).unwrap();
        assert_eq!(r.features_evaluated, 784);
    }
    drop(handle);
    run.join();
}

#[test]
fn budgeted_service_caps_features() {
    let mut snapshot = train_snapshot();
    snapshot.boundary = AnyBoundary::Budgeted { k: 50 };
    let (handle, run) = PredictionService::new(snapshot, 4, 32, 3).spawn();
    let mut gen = SynthDigits::new(21);
    let r = handle.score(gen.render(3)).unwrap();
    assert_eq!(r.features_evaluated, 50);
    drop(handle);
    run.join();
}
