//! Multi-model registry serving, end-to-end over real sockets: one
//! `serve` process hosting several independently hot-reloadable binary
//! shards plus the all-pairs multiclass ensemble, driven by mixed
//! v1 single-model and v2/v3 routed traffic through one port.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use attentive::config::ServerConfig;
use attentive::coordinator::service::{
    EnsembleSnapshot, Features, ModelSnapshot, ServingModel, VoterSnapshot,
};
use attentive::data::synth::SynthDigits;
use attentive::learner::multiclass::OneVsOneEnsemble;
use attentive::learner::pegasos::PegasosConfig;
use attentive::margin::policy::CoordinatePolicy;
use attentive::server::frame::{ErrorCode, Frame};
use attentive::server::loadgen::{self, Client, ClientMode, LoadGenConfig};
use attentive::server::protocol::{Request, Response};
use attentive::server::tcp::TcpServer;
use attentive::stst::boundary::AnyBoundary;

const DIM: usize = 784;

/// Flat binary snapshot: every weight `w`, so any inky digit image
/// scores with the sign of `w` deterministically.
fn flat_snapshot(dim: usize, w: f64) -> ModelSnapshot {
    ModelSnapshot {
        weights: vec![w; dim],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    }
}

/// Flat deterministic 3-class ensemble over `classes` 0/1/2: all-ones
/// voters make every voter vote its `pos` on a positive input, so the
/// vote is 0:2, 1:1, 2:0 → label 0; a negative input yields label 2.
fn flat_ensemble(dim: usize) -> EnsembleSnapshot {
    let classes = vec![0i64, 1, 2];
    let mut voters = Vec::new();
    for a in 0..classes.len() {
        for b in a + 1..classes.len() {
            voters.push(VoterSnapshot {
                pos: classes[a],
                neg: classes[b],
                weights: vec![1.0; dim],
                var_sn: 4.0,
            });
        }
    }
    EnsembleSnapshot {
        classes,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
        voters,
    }
}

fn registry_server(models: Vec<(String, ServingModel)>, queue: usize, workers: usize) -> TcpServer {
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers,
        queue,
        ..Default::default()
    };
    TcpServer::serve_models(&cfg, models).expect("bind loopback registry")
}

/// The acceptance scenario: ≥ 3 named binary shards plus the all-pairs
/// ensemble behind one port; mixed v1 single-model and v2/v3 routed
/// score/classify traffic; one shard hot-reloaded mid-stream; every
/// admitted request answered correctly with the right generation stamp.
#[test]
fn mixed_v1_v3_traffic_across_four_shards_with_midstream_reload() {
    let server = registry_server(
        vec![
            ("default".into(), flat_snapshot(DIM, 1.0).into()),
            ("neg".into(), flat_snapshot(DIM, -1.0).into()),
            ("wide".into(), flat_snapshot(70_000, 1.0).into()),
            ("digits".into(), flat_ensemble(DIM).into()),
        ],
        4096,
        2,
    );
    let addr = server.local_addr().to_string();

    // Background v1 single-model load (no model field anywhere): must be
    // oblivious to the other shards and to the mid-stream reload below.
    let load_addr = addr.clone();
    let load = std::thread::spawn(move || {
        loadgen::run(&LoadGenConfig {
            addr: load_addr,
            connections: 3,
            requests: 400,
            pipeline: 8,
            hard_fraction: 0.5,
            seed: 5,
            ..Default::default()
        })
        .expect("v1 loadgen")
    });

    // Control + routed traffic on a v1 JSON connection.
    let mut control = Client::connect(&addr).expect("control connect");
    let models = control.models().expect("models op");
    assert_eq!(models.len(), 4);
    assert_eq!((models[0].name.as_str(), models[0].id, models[0].kind.as_str()), ("default", 0, "binary"));
    assert_eq!((models[1].name.as_str(), models[1].id), ("neg", 1));
    assert_eq!((models[2].name.as_str(), models[2].dim), ("wide", 70_000));
    assert_eq!((models[3].name.as_str(), models[3].kind.as_str(), models[3].voters), ("digits", "ensemble", 3));

    let probe: Vec<f64> = SynthDigits::new(99).render(3);
    match control.score(probe.clone()).expect("default score") {
        Response::Score { score, .. } => assert!(score > 0.0, "default shard is all-(+1)"),
        other => panic!("expected score, got {other:?}"),
    }
    match control.score_model("neg", probe.clone()).expect("routed score") {
        Response::Score { score, .. } => assert!(score < 0.0, "neg shard is all-(-1)"),
        other => panic!("expected score, got {other:?}"),
    }
    // The wide shard has a different dimensionality entirely.
    match control
        .score_model("wide", Features::Sparse { idx: vec![69_999], val: vec![2.0] })
        .expect("wide sparse score")
    {
        Response::Score { score, features_evaluated, .. } => {
            assert!(score > 0.0);
            assert!(features_evaluated <= 1);
        }
        other => panic!("expected score, got {other:?}"),
    }
    // Classify on the ensemble shard, dense and sparse.
    match control.classify(Some("digits"), probe.clone()).expect("classify") {
        Response::Classify { label, votes, voters, features_evaluated, .. } => {
            assert_eq!(label, 0, "all-positive voters vote their pos class");
            assert_eq!((votes, voters), (2, 3));
            assert!(features_evaluated < 3 * DIM, "voters early-exit");
        }
        other => panic!("expected classify, got {other:?}"),
    }
    match control
        .classify(Some("digits"), Features::Sparse { idx: vec![7, 100], val: vec![-1.0, -2.0] })
        .expect("sparse classify")
    {
        Response::Classify { label, .. } => assert_eq!(label, 2, "negative input flips the vote"),
        other => panic!("expected classify, got {other:?}"),
    }

    // v3 binary connection: raw frames so the generation stamps are
    // observable. Route by interned id, pin generations.
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let write = |bytes: &[u8]| {
        let mut s = &stream;
        s.write_all(bytes).unwrap();
    };
    write(Request::Hello { proto: 3 }.to_line().as_bytes());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    match Response::parse(line.trim()).unwrap() {
        Response::Hello { proto: 3, gen: 1, dim } => assert_eq!(dim, DIM),
        other => panic!("expected v3 hello grant, got {other:?}"),
    }
    let sparse = |v: f64| (vec![10u32, 200, 505], vec![v, v, v]);
    // Score the neg shard (id 1), any generation: stamped gen 1.
    let (idx, val) = sparse(1.0);
    write(&Frame::ScoreSparse2 { model: 1, gen: 0, idx, val }.encode());
    match Frame::read_from(&mut reader, 1 << 20).unwrap() {
        Frame::Score { gen, score, .. } => {
            assert_eq!(gen, 1);
            assert!(score < 0.0);
        }
        other => panic!("expected score frame, got {other:?}"),
    }
    // Classify the ensemble shard (id 3): a CLASS frame, stamped.
    let (idx, val) = sparse(1.0);
    write(&Frame::ClassifySparse { model: 3, gen: 0, idx, val }.encode());
    match Frame::read_from(&mut reader, 1 << 20).unwrap() {
        Frame::Class { gen, label, votes, voters, evaluated } => {
            assert_eq!(gen, 1);
            assert_eq!(label, 0);
            assert_eq!((votes, voters), (2, 3));
            assert!(evaluated <= 9, "3 voters × nnz 3 bounds the walk");
        }
        other => panic!("expected class frame, got {other:?}"),
    }
    // Dense binary score op against the default shard.
    write(&Frame::ScoreDense { model: 0, gen: 1, val: probe.clone() }.encode());
    match Frame::read_from(&mut reader, 1 << 20).unwrap() {
        Frame::Score { gen: 1, score, .. } => assert!(score > 0.0),
        other => panic!("expected dense score frame, got {other:?}"),
    }

    // Mid-stream hot reload of ONE shard (neg → all-positive): its
    // generation bumps, its sign flips, and nothing else moves.
    assert_eq!(
        control.reload_model(Some("neg"), &flat_snapshot(DIM, 1.0).into()).expect("reload neg"),
        DIM
    );
    match control.score_model("neg", probe.clone()).expect("reloaded score") {
        Response::Score { score, .. } => assert!(score > 0.0, "reload must flip the shard"),
        other => panic!("expected score, got {other:?}"),
    }
    // Old pin on the reloaded shard sheds; new pin is stamped gen 2.
    let (idx, val) = sparse(1.0);
    write(&Frame::ScoreSparse2 { model: 1, gen: 1, idx, val }.encode());
    match Frame::read_from(&mut reader, 1 << 20).unwrap() {
        Frame::Error { code, retryable, .. } => {
            assert_eq!(code, ErrorCode::StaleGeneration);
            assert!(retryable);
        }
        other => panic!("expected stale-generation, got {other:?}"),
    }
    let (idx, val) = sparse(1.0);
    write(&Frame::ScoreSparse2 { model: 1, gen: 2, idx, val }.encode());
    match Frame::read_from(&mut reader, 1 << 20).unwrap() {
        Frame::Score { gen: 2, score, .. } => assert!(score > 0.0),
        other => panic!("expected gen-2 score frame, got {other:?}"),
    }
    // The other shards' generations did not move.
    let models = control.models().unwrap();
    assert_eq!(models.iter().map(|m| m.gen).collect::<Vec<_>>(), vec![1, 2, 1, 1]);

    // The background v1 load saw a plain single-model server throughout.
    let report = load.join().unwrap();
    assert_eq!(report.sent, 400);
    assert_eq!(report.answered, 400, "mid-stream reload of another shard drops nothing");
    assert_eq!(report.errors, 0);

    // Stats split per shard and per wire class.
    let stats = control.stats().expect("stats");
    assert_eq!(stats.models.len(), 4);
    assert!(stats.models[0].served >= 401, "default shard carried the v1 load + probes");
    assert!(stats.models[1].served >= 3, "neg shard probes");
    assert_eq!(stats.models[1].gen, 2);
    assert_eq!(stats.models[1].reloads, 1);
    assert!(stats.models[3].served >= 3, "ensemble classifies count");
    assert!(stats.wire_v1.served >= 400, "v1 JSON lines carried the loadgen");
    assert!(stats.wire_v2_binary.served >= 4, "binary frames carried the raw probes");
    assert!(stats.wire_v1.bytes > 0 && stats.wire_v2_binary.bytes > 0);
    assert_eq!(stats.reloads, 1);

    drop(reader);
    drop(stream);
    let final_stats = server.shutdown();
    assert!(final_stats.served >= 400 + 8);
}

#[test]
fn reloading_one_shard_under_load_never_stalls_or_drops_the_other() {
    let server = registry_server(
        vec![
            ("default".into(), flat_snapshot(DIM, 1.0).into()),
            ("victim".into(), flat_snapshot(DIM, -1.0).into()),
        ],
        4096,
        2,
    );
    let addr = server.local_addr().to_string();

    // Routed sparse-JSON load against the DEFAULT shard...
    let load_addr = addr.clone();
    let load = std::thread::spawn(move || {
        loadgen::run(&LoadGenConfig {
            addr: load_addr,
            connections: 3,
            requests: 600,
            pipeline: 8,
            hard_fraction: 0.5,
            mode: ClientMode::V2SparseJson,
            seed: 11,
            ..Default::default()
        })
        .expect("loadgen")
    });

    // ... while the victim shard is hammered with hot reloads.
    let mut control = Client::connect(&addr).expect("control connect");
    let mut reloads = 0u64;
    for i in 0..15 {
        let w = if i % 2 == 0 { 1.0 } else { -1.0 };
        assert_eq!(
            control.reload_model(Some("victim"), &flat_snapshot(DIM, w).into()).unwrap(),
            DIM
        );
        reloads += 1;
    }

    let report = load.join().unwrap();
    assert_eq!(report.sent, 600);
    assert_eq!(
        report.answered + report.overloaded,
        600,
        "every request on the untouched shard is answered"
    );
    assert_eq!(report.errors, 0, "no cross-shard interference errors");

    let stats = control.stats().unwrap();
    let default = &stats.models[0];
    let victim = &stats.models[1];
    assert_eq!(default.gen, 1, "default shard generation untouched by 15 reloads next door");
    assert_eq!(victim.gen as u64, 1 + reloads);
    assert_eq!(victim.reloads, reloads);
    assert!(default.served >= report.answered, "load landed on the default shard");

    // The victim still serves after the storm (15 reloads → +1 weights).
    match control.score_model("victim", SynthDigits::new(1).render(2)).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0),
        other => panic!("expected score, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn unknown_models_and_kind_mismatches_are_structured_errors() {
    let server = registry_server(
        vec![
            ("default".into(), flat_snapshot(DIM, 1.0).into()),
            ("digits".into(), flat_ensemble(DIM).into()),
        ],
        256,
        1,
    );
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();

    // Unknown model name on the JSON wire: structured, not retryable,
    // connection survives.
    match client.score_model("nope", vec![1.0; DIM]).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("unknown model"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected unknown-model error, got {other:?}"),
    }
    // classify on a binary shard / score on an ensemble shard.
    match client.classify(None, vec![1.0; DIM]).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("wrong model kind"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected wrong-kind error, got {other:?}"),
    }
    match client.score_model("digits", vec![1.0; DIM]).unwrap() {
        Response::Error { error, .. } => {
            assert!(error.contains("wrong model kind"), "got {error:?}")
        }
        other => panic!("expected wrong-kind error, got {other:?}"),
    }
    // Reload routed at a ghost shard.
    assert!(client.reload_model(Some("ghost"), &flat_snapshot(DIM, 1.0).into()).is_err());
    client.ping().expect("connection survives all rejections");

    // Same screens on the binary wire, by interned id.
    assert_eq!(client.negotiate().unwrap(), 7);
    match client.score_sparse2(99, vec![1], vec![1.0], 0).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("unknown model id"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected unknown-model error, got {other:?}"),
    }
    match client.classify_sparse(0, vec![1], vec![1.0], 0).unwrap() {
        Response::Error { error, .. } => {
            assert!(error.contains("wrong model kind"), "got {error:?}")
        }
        other => panic!("expected wrong-kind error, got {other:?}"),
    }
    // And the connection still serves both kinds afterwards.
    match client.score_sparse2(0, vec![1], vec![1.0], 0).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0),
        other => panic!("expected score, got {other:?}"),
    }
    match client.classify_sparse(1, vec![1], vec![1.0], 0).unwrap() {
        Response::Classify { label, .. } => assert_eq!(label, 0),
        other => panic!("expected classify, got {other:?}"),
    }
    server.shutdown();
}

/// The `max_nnz` knob bounds per-request compute on the JSON wire too —
/// a classify amplifies every coordinate by `C(C-1)/2` voters, so the
/// cap must not be bypassable by switching encodings.
#[test]
fn nnz_cap_applies_to_json_score_and_classify() {
    let cfg = ServerConfig { listen: "127.0.0.1:0".into(), max_nnz: 4, ..Default::default() };
    let server = TcpServer::serve_models(
        &cfg,
        vec![
            ("default".into(), flat_snapshot(DIM, 1.0).into()),
            ("digits".into(), flat_ensemble(DIM).into()),
        ],
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    let over = Features::Sparse { idx: vec![1, 2, 3, 4, 5], val: vec![1.0; 5] };
    match client.score_model("default", over.clone()).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("exceeds server cap"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected nnz-cap error, got {other:?}"),
    }
    match client.classify(Some("digits"), over).unwrap() {
        Response::Error { error, .. } => assert!(error.contains("exceeds server cap")),
        other => panic!("expected nnz-cap error, got {other:?}"),
    }
    // At the cap is fine; dense payloads are not subject to the knob.
    let at = Features::Sparse { idx: vec![1, 2, 3, 4], val: vec![1.0; 4] };
    assert!(matches!(
        client.score_model("default", at).unwrap(),
        Response::Score { .. }
    ));
    assert!(matches!(client.score(vec![0.5; DIM]).unwrap(), Response::Score { .. }));
    server.shutdown();
}

/// Property check: the serving-side ensemble classify — locally and
/// over the wire — reproduces the offline `OneVsOneEnsemble` vote
/// exactly (label AND total feature count), example by example, under
/// the deterministic sequential policy.
#[test]
fn ensemble_classify_equals_offline_one_vs_one_vote() {
    let classes = [1i64, 2, 3];
    let ds = SynthDigits::new(41).generate_classes(1_500, &[1, 2, 3]);
    let (train, test) = ds.split(0.8);
    let boundary = AnyBoundary::Constant { delta: 0.1, paper_literal: false };
    let cfg = PegasosConfig {
        lambda: 1e-2,
        policy: CoordinatePolicy::Sequential,
        seed: 3,
        ..Default::default()
    };
    let mut ensemble = OneVsOneEnsemble::new(train.dim(), &classes, cfg, boundary.clone()).unwrap();
    let order: Vec<usize> = (0..train.len()).collect();
    ensemble.train_pass(&train, &order);

    let snapshot = EnsembleSnapshot::from_trained(
        &mut ensemble,
        boundary,
        CoordinatePolicy::Sequential,
    );
    assert_eq!(snapshot.voter_count(), 3);
    let mut scratch = snapshot.make_scratch(0);

    // Offline vote vs serving-layer classify, on every test example.
    let mut disagreements = 0usize;
    for ex in test.iter() {
        let (offline_label, offline_features) = ensemble.predict(ex.features);
        let resp = snapshot.classify(&Features::Dense(ex.features.to_vec()), &mut scratch);
        let info = resp.classify.expect("classify outcome");
        if info.label != offline_label || resp.features_evaluated != offline_features {
            disagreements += 1;
        }
    }
    assert_eq!(disagreements, 0, "serving classify must equal the offline vote exactly");

    // And through the full wire stack (ensemble as the default shard).
    let server = registry_server(vec![("digits".into(), snapshot.into())], 256, 1);
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    for ex in test.iter().take(40) {
        let (offline_label, _) = ensemble.predict(ex.features);
        match client.classify(None, ex.features.to_vec()).unwrap() {
            Response::Classify { label, voters, .. } => {
                assert_eq!(label, offline_label, "wire classify disagrees with offline vote");
                assert_eq!(voters, 3);
            }
            other => panic!("expected classify, got {other:?}"),
        }
    }
    server.shutdown();
}

/// The v3 sparse frame lifts the legacy u16 index bound: a shard wider
/// than 65536 dims is servable over the binary wire.
#[test]
fn u32_indices_reach_wide_models_where_the_legacy_frame_cannot() {
    let wide_dim = 70_000;
    let server = registry_server(vec![("wide".into(), flat_snapshot(wide_dim, 1.0).into())], 64, 1);
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    assert_eq!(client.negotiate().unwrap(), 7);
    // The legacy frame cannot even express the index ...
    let err = client.score_sparse(vec![69_999], vec![1.0], 0).unwrap_err();
    assert!(err.to_string().contains("u16"), "got {err}");
    // ... the v3 frame carries it fine.
    match client.score_sparse2(0, vec![69_999], vec![1.5], 0).unwrap() {
        Response::Score { score, features_evaluated, .. } => {
            assert!(score > 0.0);
            assert!(features_evaluated <= 1);
        }
        other => panic!("expected score, got {other:?}"),
    }
    // Dense binary scoring works on the same negotiated connection.
    match client.score_dense_binary(0, vec![0.001; wide_dim], 0).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0),
        other => panic!("expected score, got {other:?}"),
    }
    server.shutdown();
}
