//! Fault-injection (chaos) suite: the `server::faultpoint` hooks drive
//! torn writes, injected delays, worker panics, and snapshot persist
//! failures against a real loopback server, and the resilient client
//! (`Client::call_retry`, loadgen `--retries`) plus the crash-recovery
//! path (`ServerConfig.snapshot_dir`) must absorb every one of them
//! without client-visible corruption.
//!
//! Faultpoint state is process-global, so every test serializes on one
//! mutex and resets the table on entry and exit. Servers are built with
//! `..Default::default()`, so `ATTENTIVE_IO_BACKEND` selects the
//! backend exactly as the CI gates do — the whole suite runs once per
//! backend.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use attentive::config::{ServerConfig, TrainerWireConfig};
use attentive::coordinator::factory::build_wire_pegasos;
use attentive::coordinator::service::{Features, ModelSnapshot};
use attentive::data::synth::SynthDigits;
use attentive::learner::OnlineLearner;
use attentive::margin::policy::CoordinatePolicy;
use attentive::server::faultpoint::{self, Point};
use attentive::server::loadgen::{Client, ClientMode, LoadGenConfig, RetryPolicy};
use attentive::server::protocol::{Request, Response, StatsReport};
use attentive::server::tcp::TcpServer;
use attentive::stst::boundary::AnyBoundary;

const DIM: usize = 784;

/// Serializes the suite: faultpoint state is process-global, so two
/// chaos tests running concurrently would see each other's faults. A
/// poisoned lock (a prior test panicked) is still a valid serializer.
static LOCK: Mutex<()> = Mutex::new(());

fn chaos_guard() -> std::sync::MutexGuard<'static, ()> {
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    guard
}

fn flat_snapshot(w: f64) -> ModelSnapshot {
    ModelSnapshot {
        weights: vec![w; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    }
}

fn loopback_server(snapshot: ModelSnapshot, queue: usize, workers: usize) -> TcpServer {
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers,
        queue,
        ..Default::default()
    };
    TcpServer::serve(&cfg, snapshot).expect("bind loopback")
}

/// One dense score request for `Client::call_retry` (JSON path: works
/// on a non-negotiated connection, so reconnects skip the handshake).
fn score_request(features: Vec<f64>) -> Request {
    Request::Score {
        id: None,
        model: None,
        features: Features::Dense(features),
        deadline_ms: None,
        priority: None,
    }
}

/// A contained worker panic answers a retryable `internal` error on the
/// live connection — and the connection (plus the respawned worker)
/// keeps serving afterwards.
#[test]
fn worker_panic_is_contained_and_connection_survives() {
    let _guard = chaos_guard();
    let server = loopback_server(flat_snapshot(1.0), 64, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let probe: Vec<f64> = SynthDigits::new(41).render(2);

    faultpoint::configure("worker-panic:1").unwrap();
    match client.score(probe.clone()).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(retryable, "a contained panic must be retryable");
            assert!(error.contains("internal"), "got {error:?}");
        }
        other => panic!("expected an internal error, got {other:?}"),
    }

    // Disarm: the same connection scores cleanly on the respawned
    // worker — the panic never escaped the evaluation.
    faultpoint::reset();
    match client.score(probe).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0, "got {score}"),
        other => panic!("expected a score, got {other:?}"),
    }
    let stats = client.stats().unwrap();
    assert!(stats.worker_panics >= 1, "panic counter must tick: {stats:?}");

    server.shutdown();
}

/// `call_retry` rides out periodic worker panics: every request lands a
/// clean score even though every third evaluation dies.
#[test]
fn call_retry_rides_out_worker_panics() {
    let _guard = chaos_guard();
    let server = loopback_server(flat_snapshot(1.0), 64, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let policy = RetryPolicy { max_retries: 4, base_backoff_ms: 1, max_backoff_ms: 4 };
    let probe: Vec<f64> = SynthDigits::new(42).render(2);

    faultpoint::configure("worker-panic:3").unwrap();
    for _ in 0..20 {
        match client.call_retry(&score_request(probe.clone()), &policy).unwrap() {
            Response::Score { score, .. } => assert!(score > 0.0, "got {score}"),
            other => panic!("retry must end in a score, got {other:?}"),
        }
    }
    assert!(client.retries() > 0, "panics every 3rd request must have forced retries");
    assert!(faultpoint::fired(Point::WorkerPanic) > 0);
    faultpoint::reset();
    server.shutdown();
}

/// Torn writes kill the connection mid-response; `call_retry`
/// reconnects and re-sends, and every answer that does arrive is intact
/// (truncation is always detectable, never silent corruption).
#[test]
fn call_retry_reconnects_through_torn_writes() {
    let _guard = chaos_guard();
    let server = loopback_server(flat_snapshot(1.0), 64, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let policy = RetryPolicy { max_retries: 10, base_backoff_ms: 1, max_backoff_ms: 4 };
    let probe: Vec<f64> = SynthDigits::new(43).render(2);

    faultpoint::configure("torn-write:5").unwrap();
    for _ in 0..30 {
        match client.call_retry(&score_request(probe.clone()), &policy).unwrap() {
            // All-(+1) weights on an inky image: any prefix of the
            // attentive walk is positive, so a sign flip (or a parse of
            // a truncated line) would be client-visible corruption.
            Response::Score { score, .. } => assert!(score > 0.0, "got {score}"),
            other => panic!("retry must end in a score, got {other:?}"),
        }
    }
    assert!(client.reconnects() > 0, "torn writes must have forced reconnects");
    assert!(faultpoint::fired(Point::TornWrite) > 0);

    // Disarm: the (reconnected) client keeps working.
    faultpoint::reset();
    match client.score(probe).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0),
        other => panic!("expected a score, got {other:?}"),
    }
    server.shutdown();
}

/// Injected write-path delay: responses still arrive, intact, just
/// late — the slow-path shape deadline knobs are tuned against.
#[test]
fn injected_delay_slows_but_does_not_corrupt() {
    let _guard = chaos_guard();
    let server = loopback_server(flat_snapshot(1.0), 64, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let probe: Vec<f64> = SynthDigits::new(44).render(2);

    faultpoint::configure("delay:1:30").unwrap();
    let t0 = Instant::now();
    match client.score(probe).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0, "got {score}"),
        other => panic!("expected a score, got {other:?}"),
    }
    let elapsed = t0.elapsed();
    assert!(elapsed >= Duration::from_millis(25), "delay fault must bite, took {elapsed:?}");
    faultpoint::reset();
    server.shutdown();
}

/// The closed-loop loadgen driver with `retries` armed absorbs torn
/// writes: every request is eventually answered, zero errors, and the
/// reconnect/retry counters surface what it cost.
#[test]
fn loadgen_retries_survive_torn_writes() {
    let _guard = chaos_guard();
    let server = loopback_server(flat_snapshot(1.0), 4096, 2);
    let addr = server.local_addr().to_string();

    faultpoint::configure("torn-write:40").unwrap();
    // One connection: write positions are then deterministic, so the
    // reconnect handshake reply (the write right after a tear) never
    // lands on a fire position itself.
    let report = attentive::server::loadgen::run(&LoadGenConfig {
        addr,
        connections: 1,
        requests: 200,
        pipeline: 4,
        mode: ClientMode::V2Binary,
        retries: 8,
        seed: 7,
        ..Default::default()
    })
    .expect("loadgen must recover");
    assert!(faultpoint::fired(Point::TornWrite) >= 1);
    faultpoint::reset();

    assert_eq!(report.answered, 200, "every request answered: {report:?}");
    assert_eq!(report.errors, 0, "torn frames must never parse: {report:?}");
    assert!(report.reconnects >= 1, "torn writes must force reconnects: {report:?}");
    assert!(report.retries >= 1, "rolled-back windows must be re-sent: {report:?}");
    server.shutdown();
}

// ---- crash recovery ------------------------------------------------------

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let dir =
            std::env::temp_dir().join(format!("attentive-chaos-{tag}-{}-{n}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        Self(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

const LDIM: usize = 16;

/// Synthetic separable stream in a small dimension, identical to the
/// serve_loopback learn suite: label = sign(a+b) on two active
/// coordinates cycling over a fixed support.
fn learn_stream(n: usize, seed: u64) -> Vec<(Vec<u32>, Vec<f64>, f64)> {
    let mut s = seed.wrapping_mul(2).wrapping_add(1);
    let mut next = move || {
        s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = s;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        (z ^ (z >> 31)) as f64 / u64::MAX as f64
    };
    (0..n)
        .map(|i| {
            let a = next() * 2.0 - 1.0;
            let b = next() * 2.0 - 1.0;
            let y = if a + b >= 0.0 { 1.0 } else { -1.0 };
            (vec![(i % 3) as u32, 3 + (i % 5) as u32], vec![a, b], y)
        })
        .collect()
}

fn zero_snapshot() -> ModelSnapshot {
    ModelSnapshot {
        weights: vec![0.0; LDIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    }
}

fn trainer_cfg() -> TrainerWireConfig {
    TrainerWireConfig {
        queue: 4096, // outsizes the stream: nothing sheds
        publish_every_updates: 1,
        publish_every_ms: 0, // count-only cadence: deterministic publishes
        lambda: 1e-2,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::WeightSampled,
        seed: 11,
        ..Default::default()
    }
}

fn recovery_server(snapshot_dir: PathBuf) -> TcpServer {
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        // One worker: the per-worker attention RNG stream then depends
        // only on (seed, scores since the last reload), so two servers
        // serving identical weights answer identical probe sequences
        // with bit-identical scores — the recovery contract under test.
        workers: 1,
        queue: 256,
        trainer: Some(trainer_cfg()),
        snapshot_dir: Some(snapshot_dir),
        ..Default::default()
    };
    TcpServer::serve_models(&cfg, vec![("default".into(), zero_snapshot().into())])
        .expect("bind loopback")
}

/// Newest generation number present on disk for the `default` shard —
/// torn files count: a burned generation still advances the sequence.
fn max_gen_on_disk(root: &std::path::Path) -> u64 {
    let dir = root.join("default");
    let Ok(entries) = std::fs::read_dir(&dir) else { return 0 };
    entries
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            let digits = name.strip_prefix("gen-")?.strip_suffix(".snap")?;
            digits.parse::<u64>().ok()
        })
        .max()
        .unwrap_or(0)
}

fn wait_for_publishes(client: &mut Client, want: u64) -> StatsReport {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let stats = client.stats().unwrap();
        let shard = stats.models.iter().find(|m| m.name == "default").expect("default shard");
        if shard.learn_publishes >= want {
            assert_eq!(shard.learn_publishes, want, "publish count overshot: {shard:?}");
            return stats;
        }
        assert!(Instant::now() < deadline, "trainer never drained: {shard:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn probe_scores(client: &mut Client, probes: &[(Vec<u32>, Vec<f64>, f64)]) -> Vec<f64> {
    probes
        .iter()
        .map(|(idx, val, _)| match client.score_sparse(idx.clone(), val.clone(), 0).unwrap() {
            // Binary frames carry the f64 verbatim (little-endian
            // bits), so equality below is bit-exactness over the wire.
            Response::Score { score, .. } => score,
            other => panic!("probe got {other:?}"),
        })
        .collect()
}

/// The tentpole end-to-end: learn → publish → persist; tear every
/// persist (including the shutdown one) and kill the server; restart
/// from the same `--snapshot-dir`; the recovered server must serve the
/// newest *valid* generation with bit-identical scores, skip every torn
/// file, and keep the generation sequence monotonic as learning
/// resumes.
#[test]
fn crash_recovery_restores_newest_valid_snapshot_bit_identically() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("recover");

    // Offline reference: the exact learner the wire trainer builds, fed
    // the same sequence, tells us how many updates (== publishes ==
    // disk generations, with publish_every_updates=1) each phase lands.
    let examples = learn_stream(150, 5);
    let mut offline = build_wire_pegasos(&trainer_cfg(), LDIM);
    let mut updates_clean = 0u64; // phase 1: first 120, true labels
    let mut updates_torn = 0u64; // phase 2: last 30, flipped labels
    for (i, (idx, val, y)) in examples.iter().enumerate() {
        let x = Features::Sparse { idx: idx.clone(), val: val.clone() }.densify(LDIM);
        let y = if i < 120 { *y } else { -*y };
        if offline.process(&x, y).updated {
            if i < 120 {
                updates_clean += 1;
            } else {
                updates_torn += 1;
            }
        }
    }
    assert!(updates_clean > 0, "phase 1 must publish at least once");
    // Flipped labels on a trained model violate the margin: phase 2 is
    // guaranteed to attempt (torn) persists.
    assert!(updates_torn > 0, "phase 2 must attempt at least one persist");

    // ---- phase 1: clean learning; every publish persists ------------
    let server = recovery_server(tmp.0.clone());
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    assert!(client.negotiate().unwrap() >= 4, "learn frames need protocol v4");
    for (idx, val, y) in &examples[..120] {
        let label: i8 = if *y > 0.0 { 1 } else { -1 };
        match client.learn_sparse(0, label, idx.clone(), val.clone()).unwrap() {
            Response::Learned { .. } => {}
            other => panic!("learn got {other:?}"),
        }
    }
    wait_for_publishes(&mut client, updates_clean);
    assert_eq!(max_gen_on_disk(&tmp.0), updates_clean, "every publish lands one gen file");

    let probes = learn_stream(40, 99);
    let clean_scores = probe_scores(&mut client, &probes);

    // ---- phase 2: every persist torn, then the "crash" ---------------
    faultpoint::configure("snapshot-fail:1").unwrap();
    for (idx, val, y) in &examples[120..] {
        let label: i8 = if *y > 0.0 { -1 } else { 1 }; // flipped: forces updates
        match client.learn_sparse(0, label, idx.clone(), val.clone()).unwrap() {
            Response::Learned { .. } => {}
            other => panic!("learn got {other:?}"),
        }
    }
    wait_for_publishes(&mut client, updates_clean + updates_torn);
    let torn_max = max_gen_on_disk(&tmp.0);
    assert!(
        torn_max >= updates_clean + updates_torn,
        "a failed persist still burns its generation: {torn_max} vs {}",
        updates_clean + updates_torn
    );
    // Keep the fault armed through shutdown: the final dirty-state
    // publish (if any) must be torn too, or phase 3 would recover
    // phase-2 weights and the bit-identity assertion below would be
    // vacuous. OnlineTrainer::shutdown joins synchronously, so reset()
    // after this line cannot race the last persist.
    drop(client);
    server.shutdown();
    assert!(faultpoint::fired(Point::SnapshotFail) >= updates_torn);
    faultpoint::reset();

    // ---- phase 3: restart from the same dir --------------------------
    let server = recovery_server(tmp.0.clone());
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    assert!(client.negotiate().unwrap() >= 4);
    let recovered_scores = probe_scores(&mut client, &probes);
    assert_eq!(
        recovered_scores, clean_scores,
        "recovery must serve the newest valid generation bit-identically, \
         skipping every torn file"
    );

    // ---- phase 4: learning resumes; generations stay monotonic -------
    let resume = learn_stream(40, 123);
    'resume: for chunk in resume.chunks(10) {
        for (idx, val, y) in chunk {
            let label: i8 = if *y > 0.0 { -1 } else { 1 }; // flipped: forces updates
            match client.learn_sparse(0, label, idx.clone(), val.clone()).unwrap() {
                Response::Learned { .. } => {}
                other => panic!("learn got {other:?}"),
            }
        }
        let chunk_deadline = Instant::now() + Duration::from_secs(2);
        while Instant::now() < chunk_deadline {
            if max_gen_on_disk(&tmp.0) > torn_max {
                break 'resume;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    let resumed_max = max_gen_on_disk(&tmp.0);
    assert!(
        resumed_max > torn_max,
        "post-recovery persists must extend the sequence past the burned \
         generations: {resumed_max} vs {torn_max}"
    );
    server.shutdown();
}
