//! Counting-allocator proof of the transport layer's allocation-free
//! steady-state score path.
//!
//! The claim under test: once buffers are warm, handling one binary
//! score request at the transport layer — reading the frame body into a
//! reusable buffer, zero-copy decoding ([`FrameRef::decode_borrowed`]),
//! in-place validation, and serializing the response into a reusable
//! buffer ([`Frame::encode_into`]) — performs **zero** heap
//! allocations. The one deliberate exception is admission
//! ([`pairs_to_features_u32`]): the owned `Features` handed to the
//! worker queue is a service-layer cost, measured separately below so
//! a regression can be attributed to the right layer.
//!
//! The counting `#[global_allocator]` wraps `System` for this whole
//! test binary; each measurement section is single-threaded, so the
//! global counter is exact.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use attentive::server::bufpool::BufPool;
use attentive::server::frame::{
    self, Frame, FrameRef,
};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A realloc that moves is an allocation for our purposes: the
        // steady-state claim is that buffers never grow.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// One steady-state transport iteration: borrow-decode the request
/// body, screen it in place, and serialize a response into `out`.
fn transport_iteration(body: &[u8], gen: u32, out: &mut Vec<u8>) {
    let frame = FrameRef::decode_borrowed(body).expect("decode");
    let FrameRef::ScoreSparse2 { pairs, .. } = frame else {
        panic!("expected sparse2, got {frame:?}")
    };
    frame::validate_pairs_u32(pairs).expect("valid payload");
    out.clear();
    Frame::Score { gen, evaluated: frame.nnz() as u32, score: 1.25 }.encode_into(out);
}

/// One sequential test driving every scenario: the allocation counter
/// is process-global, so the measured sections must never run
/// concurrently (libtest would otherwise interleave them).
#[test]
fn transport_allocation_accounting() {
    steady_state_binary_score_path_is_allocation_free();
    steady_state_batch_score_path_is_allocation_free();
    admission_is_the_only_allocating_stage_and_is_bounded();
    bufpool_round_trips_without_allocating_after_warmup();
    read_body_loop_is_allocation_free_at_steady_state();
}

fn steady_state_binary_score_path_is_allocation_free() {
    // An MNIST-density sparse request (150 nonzeros of 784).
    let idx: Vec<u32> = (0..150u32).map(|i| i * 5).collect();
    let val: Vec<f64> = idx.iter().map(|&i| 0.25 + i as f64 * 1e-3).collect();
    let wire = Frame::ScoreSparse2 { model: 0, gen: 0, idx, val }.encode();
    let body = &wire[4..];

    // Warm-up: let the response buffer reach steady-state capacity.
    let mut out = Vec::new();
    for g in 0..4 {
        transport_iteration(body, g, &mut out);
    }

    let before = allocs();
    for g in 0..1_000u32 {
        transport_iteration(body, g, &mut out);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "1000 steady-state transport iterations must not touch the allocator, saw {delta}"
    );
    // Sanity: the loop really did produce responses.
    let (resp, _) = Frame::decode(&out, 1 << 20).expect("response decodes");
    assert!(matches!(resp, Frame::Score { gen: 999, .. }));
}

/// One steady-state batched iteration: borrow-decode a `SCORE_BATCH`
/// body, screen every example's pairs in place, and render the
/// per-row `SCORE_BATCH_RESP` into `out`. Returns the row count.
fn batch_transport_iteration(body: &[u8], gen: u32, out: &mut Vec<u8>) -> usize {
    let frame = FrameRef::decode_borrowed(body).expect("decode");
    let FrameRef::ScoreBatch { count, examples, .. } = frame else {
        panic!("expected batch, got {frame:?}")
    };
    out.clear();
    let mut enc = Frame::begin_score_batch_resp(out, gen);
    let mut rows = 0usize;
    for pairs in frame::batch_pairs(examples) {
        frame::validate_pairs_u32(pairs).expect("valid payload");
        enc.push_result(frame::BATCH_STATUS_OK, (pairs.len() / 12) as u32, 0.75);
        rows += 1;
    }
    assert_eq!(rows, count);
    enc.finish();
    rows
}

/// The v6 batch path inherits the transport claim: one `SCORE_BATCH`
/// frame of many examples decodes, screens, and answers through the
/// same two reusable buffers with zero allocations at steady state —
/// per-example cost included.
fn steady_state_batch_score_path_is_allocation_free() {
    // 16 MNIST-density examples in one frame.
    let examples: Vec<(Vec<u32>, Vec<f64>)> = (0..16usize)
        .map(|e| {
            let idx: Vec<u32> = (0..150u32).map(|i| i * 5 + (e % 3) as u32).collect();
            let val: Vec<f64> = idx.iter().map(|&i| 0.25 + i as f64 * 1e-3).collect();
            (idx, val)
        })
        .collect();
    let mut wire = Vec::new();
    let mut enc = Frame::begin_score_batch(&mut wire, 0, 0);
    for (idx, val) in &examples {
        enc.push_example(idx, val);
    }
    enc.finish();
    let body = &wire[4..];

    // Warm-up: the response buffer reaches steady-state capacity.
    let mut out = Vec::new();
    for g in 0..4 {
        batch_transport_iteration(body, g, &mut out);
    }

    let before = allocs();
    for g in 0..1_000u32 {
        assert_eq!(batch_transport_iteration(body, g, &mut out), 16);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "1000 steady-state batch iterations must not touch the allocator, saw {delta}"
    );
    // Sanity: the last response decodes to 16 OK rows.
    let (resp, _) = Frame::decode(&out, 1 << 20).expect("response decodes");
    let Frame::ScoreBatchResp { gen: 999, results } = resp else {
        panic!("expected batch response, got {resp:?}")
    };
    assert_eq!(results.len(), 16);
    assert!(results.iter().all(|r| r.status == frame::BATCH_STATUS_OK));
}

fn admission_is_the_only_allocating_stage_and_is_bounded() {
    let idx: Vec<u32> = (0..150u32).map(|i| i * 5).collect();
    let val = vec![1.0f64; 150];
    let wire = Frame::ScoreSparse2 { model: 0, gen: 0, idx, val }.encode();
    let body = &wire[4..];
    let FrameRef::ScoreSparse2 { pairs, .. } = FrameRef::decode_borrowed(body).unwrap() else {
        panic!("expected sparse2")
    };
    // Warm up allocator internals.
    drop(frame::pairs_to_features_u32(pairs));
    let before = allocs();
    let features = frame::pairs_to_features_u32(pairs);
    let delta = allocs() - before;
    assert!(
        (1..=2).contains(&delta),
        "admission materializes exactly the idx/val vectors (with_capacity, no regrowth), \
         saw {delta} allocations"
    );
    assert_eq!(features.nnz(), 150);
}

fn bufpool_round_trips_without_allocating_after_warmup() {
    let pool = BufPool::serving_default();
    // Warm-up: one buffer grown to working size, returned to the pool.
    let mut buf = pool.get();
    buf.resize(8 * 1024, 0);
    pool.put(buf);

    let before = allocs();
    for i in 0..1_000usize {
        let mut buf = pool.get();
        // Typical response-render usage within warmed capacity.
        buf.extend_from_slice(&[0u8; 64]);
        buf.extend_from_slice(&(i as u32).to_le_bytes());
        pool.put(buf);
    }
    let delta = allocs() - before;
    assert_eq!(delta, 0, "pooled buffer churn must be allocation-free, saw {delta}");
    let stats = pool.stats();
    assert_eq!(stats.misses, 1, "only the warm-up checkout missed");
    assert_eq!(stats.hits, 1_000);
}

/// The reusable body reader reaches zero allocation too: same-size
/// frames through one buffer after warm-up.
fn read_body_loop_is_allocation_free_at_steady_state() {
    let mut stream_bytes = Vec::new();
    for g in 0..64u32 {
        Frame::Score { gen: g, evaluated: 7, score: 0.5 }.encode_into(&mut stream_bytes);
    }
    let mut body = Vec::new();
    // Warm-up pass.
    let mut cursor = std::io::Cursor::new(&stream_bytes[..]);
    Frame::read_body(&mut cursor, &mut body, 1 << 20).unwrap();

    let before = allocs();
    let mut decoded = 0u32;
    while Frame::read_body(&mut cursor, &mut body, 1 << 20).is_ok() {
        let frame = FrameRef::decode_borrowed(&body).unwrap();
        assert!(matches!(frame, FrameRef::Response(_)));
        decoded += 1;
    }
    let delta = allocs() - before;
    assert_eq!(delta, 0, "read_body reuse must not allocate, saw {delta}");
    assert_eq!(decoded, 63);
}
