//! Loopback integration for the network serving subsystem: TCP server ×
//! loadgen client × hot reload × backpressure, end-to-end over real
//! sockets.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use attentive::config::ServerConfig;
use attentive::coordinator::service::ModelSnapshot;
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::synth::SynthDigits;
use attentive::data::task::BinaryTask;
use attentive::learner::attentive::attentive_pegasos;
use attentive::margin::policy::CoordinatePolicy;
use attentive::server::loadgen::{self, Client, LoadGenConfig};
use attentive::server::protocol::Response;
use attentive::server::tcp::TcpServer;
use attentive::stst::boundary::AnyBoundary;

const DIM: usize = 784;

/// A flat hand-built snapshot: every weight `w`, so any all-nonnegative
/// digit image scores with the sign of `w` — deterministically, whatever
/// the coordinate order. Early exits are guaranteed on inky images.
fn flat_snapshot(w: f64) -> ModelSnapshot {
    ModelSnapshot {
        weights: vec![w; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    }
}

/// Train a real attentive model on the synthetic 2-vs-3 task.
fn trained_snapshot() -> ModelSnapshot {
    let ds = SynthDigits::new(17).generate_classes(1_200, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).unwrap();
    let mut learner = attentive_pegasos(task.dim(), 1e-2, 0.1);
    Trainer::new(TrainerConfig { epochs: 2, eval_every: 0, curves: false, ..Default::default() })
        .fit(&mut learner, &task);
    ModelSnapshot::from_trained(
        &mut learner,
        AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        CoordinatePolicy::Permuted,
    )
}

fn loopback_server(snapshot: ModelSnapshot, queue: usize, workers: usize) -> TcpServer {
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers,
        queue,
        ..Default::default()
    };
    TcpServer::serve(&cfg, snapshot).expect("bind loopback")
}

#[test]
fn thousand_requests_with_midstream_hot_reload() {
    let server = loopback_server(trained_snapshot(), 4096, 2);
    let addr = server.local_addr().to_string();

    // Background: >= 1k mixed easy/hard requests from the loadgen client.
    let load_addr = addr.clone();
    let load = std::thread::spawn(move || {
        loadgen::run(&LoadGenConfig {
            addr: load_addr,
            connections: 4,
            requests: 1_000,
            pipeline: 8,
            hard_fraction: 0.5,
            seed: 3,
        })
        .expect("loadgen")
    });

    // Control channel on its own connection, mid-stream.
    let mut control = Client::connect(&addr).expect("control connect");
    control.ping().expect("ping");
    std::thread::sleep(std::time::Duration::from_millis(10));

    let probe: Vec<f64> = SynthDigits::new(555).render(2);
    assert_eq!(control.reload(&flat_snapshot(1.0)).expect("reload +1"), DIM);
    let up = match control.score(probe.clone()).expect("probe +1") {
        Response::Score { score, features_evaluated, .. } => {
            assert!(features_evaluated <= DIM);
            score
        }
        other => panic!("probe got {other:?}"),
    };
    assert!(up > 0.0, "all-(+1) model must score an inky image positive, got {up}");

    assert_eq!(control.reload(&flat_snapshot(-1.0)).expect("reload -1"), DIM);
    let down = match control.score(probe).expect("probe -1") {
        Response::Score { score, .. } => score,
        other => panic!("probe got {other:?}"),
    };
    assert!(down < 0.0, "hot reload must change the prediction, got {down}");

    // Every request answered, none dropped, none shed, attention saves.
    let report = load.join().unwrap();
    assert_eq!(report.sent, 1_000);
    assert_eq!(report.answered, 1_000, "hot reload must not drop a request");
    assert_eq!(report.overloaded, 0);
    assert_eq!(report.errors, 0);
    assert!(
        report.avg_features() < DIM as f64,
        "avg features/request {} must beat full evaluation",
        report.avg_features()
    );

    let stats = control.stats().expect("stats");
    assert_eq!(stats.reloads, 2);
    assert!(stats.served >= 1_002, "loadgen + probes all served, got {}", stats.served);
    assert!(stats.early_exit_rate > 0.0);
    assert!(stats.req_per_s > 0.0);

    let final_stats = server.shutdown();
    assert!(final_stats.served >= 1_002);
}

#[test]
fn malformed_lines_and_dim_mismatch_keep_connection_alive() {
    let server = loopback_server(flat_snapshot(1.0), 64, 1);
    let addr = server.local_addr().to_string();

    // Raw socket: garbage line first, then a valid ping on the same
    // connection.
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let write = |s: &str| {
        let mut stream = &stream;
        stream.write_all(s.as_bytes()).unwrap();
    };
    let mut read_line = || {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "connection closed early");
        Response::parse(line.trim()).expect("parseable response")
    };
    write("this is not json\n");
    match read_line() {
        Response::Error { retryable, .. } => assert!(!retryable),
        other => panic!("expected error, got {other:?}"),
    }
    write("{\"op\":\"ping\"}\n");
    assert!(matches!(read_line(), Response::Pong), "connection must survive a bad line");
    drop(reader);
    drop(stream);

    // Typed client: wrong dimensionality is a clean, non-retryable error.
    let mut client = Client::connect(&addr).unwrap();
    match client.score(vec![1.0, 2.0, 3.0]).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("dimension"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected dim error, got {other:?}"),
    }
    match client.score(vec![0.5; DIM]).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0),
        other => panic!("expected score, got {other:?}"),
    }

    let stats = client.stats().unwrap();
    assert_eq!(stats.protocol_errors, 1);
    server.shutdown();
}

#[test]
fn overload_sheds_explicitly_and_recovers() {
    // Tiny admission queue + single worker: pipelined floods may be shed,
    // but every request must still get an explicit response.
    let snapshot = ModelSnapshot {
        // Zero weights never clear the boundary -> every request walks
        // all 784 coordinates, keeping the worker busy enough to fill the
        // one-slot queue under a pipelined flood.
        weights: vec![0.0; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    };
    let server = loopback_server(snapshot, 1, 1);
    let addr = server.local_addr().to_string();

    let report = loadgen::run(&LoadGenConfig {
        addr: addr.clone(),
        connections: 4,
        requests: 400,
        pipeline: 32,
        hard_fraction: 1.0,
        seed: 9,
    })
    .expect("loadgen");
    assert_eq!(report.sent, 400);
    assert_eq!(
        report.answered + report.overloaded,
        400,
        "every request gets a response: scored or an explicit overloaded shed"
    );
    assert_eq!(report.errors, 0);

    // The server survives the flood and still answers.
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.overloaded, report.overloaded);
    server.shutdown();
}

#[test]
fn stats_endpoint_reports_attention_savings() {
    let server = loopback_server(flat_snapshot(1.0), 256, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let mut gen = SynthDigits::new(77);
    for i in 0..50 {
        let digit = if i % 2 == 0 { 2u8 } else { 3u8 };
        match client.score(gen.render(digit)).unwrap() {
            Response::Score { features_evaluated, .. } => {
                assert!(features_evaluated < DIM, "inky image under flat weights exits early")
            }
            other => panic!("expected score, got {other:?}"),
        }
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.served, 50);
    assert!(stats.early_exit_rate > 0.9, "got {}", stats.early_exit_rate);
    assert!(stats.avg_features < DIM as f64);
    assert!(
        stats.features_p50 < DIM as u64,
        "histogram p50 {} must sit below full evaluation",
        stats.features_p50
    );
    assert!(stats.features_p99 >= stats.features_p50);
    assert!(stats.uptime_s > 0.0);
    server.shutdown();
}
