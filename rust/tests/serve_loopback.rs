//! Loopback integration for the network serving subsystem: TCP server ×
//! loadgen client × hot reload × backpressure, end-to-end over real
//! sockets.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use attentive::config::{BrownoutConfig, IoBackend, ServerConfig, TrainerWireConfig};
use attentive::coordinator::factory::build_wire_pegasos;
use attentive::coordinator::service::{Features, Lane, ModelSnapshot};
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::synth::SynthDigits;
use attentive::data::task::BinaryTask;
use attentive::learner::attentive::attentive_pegasos;
use attentive::learner::OnlineLearner;
use attentive::margin::policy::CoordinatePolicy;
use attentive::server::frame::{ErrorCode, Frame, BATCH_STATUS_OK, LANE_BULK, LANE_DEFAULT};
use attentive::server::loadgen::{self, Client, ClientMode, LoadGenConfig};
use attentive::server::protocol::{Request, Response};
use attentive::server::tcp::TcpServer;
use attentive::stst::boundary::AnyBoundary;

const DIM: usize = 784;

/// A flat hand-built snapshot: every weight `w`, so any all-nonnegative
/// digit image scores with the sign of `w` — deterministically, whatever
/// the coordinate order. Early exits are guaranteed on inky images.
fn flat_snapshot(w: f64) -> ModelSnapshot {
    ModelSnapshot {
        weights: vec![w; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    }
}

/// Train a real attentive model on the synthetic 2-vs-3 task.
fn trained_snapshot() -> ModelSnapshot {
    let ds = SynthDigits::new(17).generate_classes(1_200, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).unwrap();
    let mut learner = attentive_pegasos(task.dim(), 1e-2, 0.1);
    Trainer::new(TrainerConfig { epochs: 2, eval_every: 0, curves: false, ..Default::default() })
        .fit(&mut learner, &task);
    ModelSnapshot::from_trained(
        &mut learner,
        AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        CoordinatePolicy::Permuted,
    )
}

fn loopback_server(snapshot: ModelSnapshot, queue: usize, workers: usize) -> TcpServer {
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers,
        queue,
        ..Default::default()
    };
    TcpServer::serve(&cfg, snapshot).expect("bind loopback")
}

#[test]
fn thousand_requests_with_midstream_hot_reload() {
    let server = loopback_server(trained_snapshot(), 4096, 2);
    let addr = server.local_addr().to_string();

    // Background: >= 1k mixed easy/hard requests from the loadgen client.
    let load_addr = addr.clone();
    let load = std::thread::spawn(move || {
        loadgen::run(&LoadGenConfig {
            addr: load_addr,
            connections: 4,
            requests: 1_000,
            pipeline: 8,
            hard_fraction: 0.5,
            seed: 3,
            ..Default::default()
        })
        .expect("loadgen")
    });

    // Control channel on its own connection, mid-stream.
    let mut control = Client::connect(&addr).expect("control connect");
    control.ping().expect("ping");
    std::thread::sleep(std::time::Duration::from_millis(10));

    let probe: Vec<f64> = SynthDigits::new(555).render(2);
    assert_eq!(control.reload(&flat_snapshot(1.0)).expect("reload +1"), DIM);
    let up = match control.score(probe.clone()).expect("probe +1") {
        Response::Score { score, features_evaluated, .. } => {
            assert!(features_evaluated <= DIM);
            score
        }
        other => panic!("probe got {other:?}"),
    };
    assert!(up > 0.0, "all-(+1) model must score an inky image positive, got {up}");

    assert_eq!(control.reload(&flat_snapshot(-1.0)).expect("reload -1"), DIM);
    let down = match control.score(probe).expect("probe -1") {
        Response::Score { score, .. } => score,
        other => panic!("probe got {other:?}"),
    };
    assert!(down < 0.0, "hot reload must change the prediction, got {down}");

    // Every request answered, none dropped, none shed, attention saves.
    let report = load.join().unwrap();
    assert_eq!(report.sent, 1_000);
    assert_eq!(report.answered, 1_000, "hot reload must not drop a request");
    assert_eq!(report.overloaded, 0);
    assert_eq!(report.errors, 0);
    assert!(
        report.avg_features() < DIM as f64,
        "avg features/request {} must beat full evaluation",
        report.avg_features()
    );

    let stats = control.stats().expect("stats");
    assert_eq!(stats.reloads, 2);
    assert!(stats.served >= 1_002, "loadgen + probes all served, got {}", stats.served);
    assert!(stats.early_exit_rate > 0.0);
    assert!(stats.req_per_s > 0.0);

    let final_stats = server.shutdown();
    assert!(final_stats.served >= 1_002);
}

#[test]
fn malformed_lines_and_dim_mismatch_keep_connection_alive() {
    let server = loopback_server(flat_snapshot(1.0), 64, 1);
    let addr = server.local_addr().to_string();

    // Raw socket: garbage line first, then a valid ping on the same
    // connection.
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let write = |s: &str| {
        let mut stream = &stream;
        stream.write_all(s.as_bytes()).unwrap();
    };
    let mut read_line = || {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "connection closed early");
        Response::parse(line.trim()).expect("parseable response")
    };
    write("this is not json\n");
    match read_line() {
        Response::Error { retryable, .. } => assert!(!retryable),
        other => panic!("expected error, got {other:?}"),
    }
    write("{\"op\":\"ping\"}\n");
    assert!(matches!(read_line(), Response::Pong), "connection must survive a bad line");
    drop(reader);
    drop(stream);

    // Typed client: wrong dimensionality is a clean, non-retryable error.
    let mut client = Client::connect(&addr).unwrap();
    match client.score(vec![1.0, 2.0, 3.0]).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("dimension"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected dim error, got {other:?}"),
    }
    match client.score(vec![0.5; DIM]).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0),
        other => panic!("expected score, got {other:?}"),
    }

    let stats = client.stats().unwrap();
    assert_eq!(stats.protocol_errors, 1);
    server.shutdown();
}

#[test]
fn overload_sheds_explicitly_and_recovers() {
    // Tiny admission queue + single worker: pipelined floods may be shed,
    // but every request must still get an explicit response.
    let snapshot = ModelSnapshot {
        // Zero weights never clear the boundary -> every request walks
        // all 784 coordinates, keeping the worker busy enough to fill the
        // one-slot queue under a pipelined flood.
        weights: vec![0.0; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    };
    let server = loopback_server(snapshot, 1, 1);
    let addr = server.local_addr().to_string();

    let report = loadgen::run(&LoadGenConfig {
        addr: addr.clone(),
        connections: 4,
        requests: 400,
        pipeline: 32,
        hard_fraction: 1.0,
        seed: 9,
        ..Default::default()
    })
    .expect("loadgen");
    assert_eq!(report.sent, 400);
    assert_eq!(
        report.answered + report.overloaded,
        400,
        "every request gets a response: scored or an explicit overloaded shed"
    );
    assert_eq!(report.errors, 0);

    // The server survives the flood and still answers.
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.overloaded, report.overloaded);
    server.shutdown();
}

#[test]
fn mixed_v1_and_v2_clients_share_one_server() {
    // One server, three concurrent load generators on different wires —
    // a v1-only client (today's loadgen syntax) must keep working,
    // unmodified, next to v2 JSON-sparse and v2 binary clients.
    let server = loopback_server(trained_snapshot(), 4096, 2);
    let addr = server.local_addr().to_string();

    let run_mode = |mode: ClientMode, seed: u64| {
        let addr = addr.clone();
        std::thread::spawn(move || {
            loadgen::run(&LoadGenConfig {
                addr,
                connections: 2,
                requests: 300,
                pipeline: 8,
                hard_fraction: 0.5,
                mode,
                sparse_eps: 0.05,
                seed,
                ..Default::default()
            })
            .expect("loadgen")
        })
    };
    let v1 = run_mode(ClientMode::V1Dense, 21);
    let v2j = run_mode(ClientMode::V2SparseJson, 22);
    let v2b = run_mode(ClientMode::V2Binary, 23);

    let mut total_answered = 0;
    for (name, join) in [("v1-dense", v1), ("v2-sparse-json", v2j), ("v2-binary", v2b)] {
        let report = join.join().unwrap();
        assert_eq!(report.sent, 300, "{name}");
        assert_eq!(report.answered + report.overloaded, 300, "{name}: all answered");
        assert_eq!(report.errors, 0, "{name}: no protocol errors");
        assert!(
            report.avg_features() < DIM as f64,
            "{name}: attention must save features, avg {}",
            report.avg_features()
        );
        total_answered += report.answered;
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, total_answered, "every scored request is counted once");
}

#[test]
fn v2_negotiated_client_scores_sparse_and_runs_control_ops() {
    let server = loopback_server(flat_snapshot(1.0), 256, 1);
    let addr = server.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    assert_eq!(client.proto(), 1);
    assert_eq!(client.negotiate().unwrap(), 7, "server grants the full v7 capability set");
    assert_eq!(client.proto(), 7);

    // Native sparse frame: 3 nonzeros, all-ones model -> positive score
    // touching at most 3 coordinates.
    match client.score_sparse(vec![10, 200, 505], vec![0.9, 0.8, 0.7], 0).unwrap() {
        Response::Score { score, features_evaluated, .. } => {
            assert!(score > 0.0);
            assert!(features_evaluated <= 3, "sparse walk bounded by nnz");
        }
        other => panic!("expected score, got {other:?}"),
    }

    // Dense scoring and control ops ride the JSON envelope frames.
    match client.score(vec![0.5; DIM]).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0),
        other => panic!("expected score, got {other:?}"),
    }
    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.served, 2);

    // Generation pinning: gen 1 is current, gen 42 is stale.
    assert!(matches!(
        client.score_sparse(vec![1], vec![1.0], 1).unwrap(),
        Response::Score { .. }
    ));
    match client.score_sparse(vec![1], vec![1.0], 42).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("generation"), "got {error:?}");
            assert!(retryable, "stale generation is retryable");
        }
        other => panic!("expected stale-generation error, got {other:?}"),
    }

    // Hot reload bumps the generation; the old pin now sheds, the new
    // one works.
    client.reload(&flat_snapshot(-1.0)).unwrap();
    match client.score_sparse(vec![1], vec![1.0], 1).unwrap() {
        Response::Error { retryable: true, .. } => {}
        other => panic!("expected stale error after reload, got {other:?}"),
    }
    match client.score_sparse(vec![1], vec![1.0], 2).unwrap() {
        Response::Score { score, .. } => assert!(score < 0.0, "reloaded sign"),
        other => panic!("expected score, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn v2_rejects_malformed_sparse_payloads_with_structured_errors() {
    let server = loopback_server(flat_snapshot(1.0), 64, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    client.negotiate().unwrap();

    // Non-finite value: structured NonFinite error, connection lives.
    match client.score_sparse(vec![3], vec![f64::NAN], 0).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("non-finite"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected non-finite error, got {other:?}"),
    }
    // Unsorted support: BadRequest, connection lives.
    match client.score_sparse(vec![9, 3], vec![1.0, 1.0], 0).unwrap() {
        Response::Error { error, .. } => {
            assert!(error.contains("increasing"), "got {error:?}")
        }
        other => panic!("expected bad-request error, got {other:?}"),
    }
    // Out-of-range index: DimMismatch.
    match client.score_sparse(vec![5_000], vec![1.0], 0).unwrap() {
        Response::Error { error, .. } => assert!(error.contains("dimension"), "got {error:?}"),
        other => panic!("expected dim error, got {other:?}"),
    }
    // The connection still serves after all three rejections.
    match client.score_sparse(vec![5], vec![1.0], 0).unwrap() {
        Response::Score { score, .. } => assert!(score > 0.0),
        other => panic!("expected score, got {other:?}"),
    }

    // And the sparse JSON form gets the same screening on a v1 line:
    // the client-side encoder happily serializes the duplicate support,
    // the server rejects it with a structured, non-retryable error.
    let mut v1 = Client::connect(&addr).unwrap();
    let dup = attentive::server::protocol::Request::Score {
        id: None,
        model: None,
        features: Features::Sparse { idx: vec![2, 2], val: vec![1.0, 1.0] },
        deadline_ms: None,
        priority: None,
    };
    match v1.call(&dup).unwrap() {
        Response::Error { error, retryable, .. } => {
            assert!(error.contains("increasing"), "got {error:?}");
            assert!(!retryable);
        }
        other => panic!("expected structured rejection, got {other:?}"),
    }
    server.shutdown();
}

/// Batch ≡ singles, bit for bit: k examples scored one frame at a time
/// on one server must match the same k examples in a single
/// `SCORE_BATCH` frame on an identically configured twin. Twin servers
/// (not one server queried twice) because the Permuted order policy
/// advances a worker-local RNG stream per request — identical configs
/// replay identical streams, so any divergence is the batch path's
/// fault, not the RNG's.
fn batch_matches_singles_on(backend: IoBackend) {
    let snapshot = trained_snapshot();
    let serve = || {
        let cfg = ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 1,
            queue: 256,
            io_backend: backend,
            ..Default::default()
        };
        TcpServer::serve(&cfg, snapshot.clone()).expect("bind loopback")
    };

    // Twelve sparse digit renders, classes interleaved so scores land
    // on both sides of zero.
    let mut digits = SynthDigits::new(41);
    let examples: Vec<(Vec<u32>, Vec<f64>)> = (0..12)
        .map(|i| {
            let dense = digits.render(if i % 2 == 0 { 2 } else { 3 });
            let (mut idx, mut val) = (Vec::new(), Vec::new());
            Features::sparsify_into(&dense, 0.05, &mut idx, &mut val);
            (idx, val)
        })
        .collect();

    // Server A: one frame per example.
    let a = serve();
    let mut client = Client::connect(&a.local_addr().to_string()).unwrap();
    assert_eq!(client.negotiate().unwrap(), 7);
    let singles: Vec<(f64, usize)> = examples
        .iter()
        .map(|(idx, val)| match client.score_sparse2(0, idx.clone(), val.clone(), 0).unwrap() {
            Response::Score { score, features_evaluated, .. } => (score, features_evaluated),
            other => panic!("single got {other:?}"),
        })
        .collect();
    a.shutdown();

    // Server B: the same examples in one SCORE_BATCH frame.
    let b = serve();
    let mut client = Client::connect(&b.local_addr().to_string()).unwrap();
    assert_eq!(client.negotiate().unwrap(), 7);
    let rows = client.score_batch(0, 0, &examples).unwrap();
    assert_eq!(rows.len(), examples.len());
    for (i, (row, (score, evaluated))) in rows.iter().zip(&singles).enumerate() {
        assert_eq!(row.status, BATCH_STATUS_OK, "row {i}");
        assert_eq!(
            row.score.to_bits(),
            score.to_bits(),
            "row {i}: batch must be bit-identical to singles ({} vs {score})",
            row.score
        );
        assert_eq!(row.evaluated as usize, *evaluated, "row {i}: same attention spend");
    }
    b.shutdown();

    // Server C: the JSON `score-batch` twin on a plain v1 connection.
    // The JSON float encoder round-trips f64 exactly, so bit-equality
    // must survive the text wire too.
    let c = serve();
    let mut client = Client::connect(&c.local_addr().to_string()).unwrap();
    let features: Vec<Features> = examples
        .iter()
        .map(|(idx, val)| Features::Sparse { idx: idx.clone(), val: val.clone() })
        .collect();
    match client.score_batch_json(None, features).unwrap() {
        Response::ScoreBatch { results, .. } => {
            assert_eq!(results.len(), examples.len());
            for (i, (row, (score, evaluated))) in results.iter().zip(&singles).enumerate() {
                assert!(row.error.is_none(), "row {i}: {:?}", row.error);
                assert_eq!(
                    row.score.to_bits(),
                    score.to_bits(),
                    "row {i}: JSON twin must stay bit-identical"
                );
                assert_eq!(row.features_evaluated, *evaluated, "row {i}");
            }
        }
        other => panic!("score-batch got {other:?}"),
    }
    c.shutdown();
}

#[test]
fn batch_scoring_is_bit_identical_to_singles() {
    batch_matches_singles_on(IoBackend::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn batch_scoring_is_bit_identical_to_singles_on_event_loop() {
    batch_matches_singles_on(IoBackend::EventLoop);
}

#[test]
fn one_bad_batch_example_never_poisons_its_batchmates() {
    let server = loopback_server(flat_snapshot(1.0), 256, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    assert_eq!(client.negotiate().unwrap(), 7);

    // Two clean examples bracket three different per-example rejects:
    // a non-finite value, an unsorted support, an out-of-range index.
    let examples: Vec<(Vec<u32>, Vec<f64>)> = vec![
        (vec![3, 40], vec![0.9, 0.8]),
        (vec![7], vec![f64::NAN]),
        (vec![9, 4], vec![1.0, 1.0]),
        (vec![5_000], vec![1.0]),
        (vec![2, 300], vec![0.5, 0.25]),
    ];
    let rows = client.score_batch(0, 0, &examples).unwrap();
    assert_eq!(rows.len(), 5);
    let expect = [
        BATCH_STATUS_OK,
        ErrorCode::NonFinite as u8,
        ErrorCode::BadRequest as u8,
        ErrorCode::DimMismatch as u8,
        BATCH_STATUS_OK,
    ];
    for (i, (row, want)) in rows.iter().zip(expect).enumerate() {
        assert_eq!(row.status, want, "row {i}");
        if row.status == BATCH_STATUS_OK {
            assert!(row.score > 0.0, "row {i}: flat +1 model scores inky input positive");
        } else {
            assert_eq!(row.evaluated, 0, "row {i}: a rejected example spends nothing");
            assert_eq!(row.score.to_bits(), 0.0f64.to_bits(), "row {i}: zeroed payload");
        }
    }

    // Whole-batch failures stay whole-batch: a stale generation pin
    // answers one error frame, not five rows.
    let err = client.score_batch(0, 42, &examples).expect_err("stale pin must fail");
    assert!(err.to_string().contains("generation"), "got {err}");

    // The connection survives both shapes of failure.
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn raw_v2_frames_with_bad_framing_close_the_connection() {
    let server = loopback_server(flat_snapshot(1.0), 64, 1);
    let addr = server.local_addr().to_string();

    // Handshake by hand on a raw socket.
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let write = |bytes: &[u8]| {
        let mut s = &stream;
        s.write_all(bytes).unwrap();
    };
    write(b"{\"op\":\"hello\",\"proto\":2}\n");
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    match Response::parse(line.trim()).unwrap() {
        Response::Hello { proto: 2, gen: 1, dim } => assert_eq!(dim, DIM),
        other => panic!("expected hello grant, got {other:?}"),
    }

    // A frame whose length prefix exceeds the server cap: the server
    // answers with a BadFrame error frame, then closes.
    write(&u32::MAX.to_le_bytes());
    match Frame::read_from(&mut reader, 1 << 20).unwrap() {
        Frame::Error { code, retryable, .. } => {
            assert_eq!(code, ErrorCode::BadFrame);
            assert!(!retryable);
        }
        other => panic!("expected BadFrame, got {other:?}"),
    }
    // Connection is gone: next read sees EOF.
    let mut probe = [0u8; 1];
    use std::io::Read as _;
    assert_eq!(reader.read(&mut probe).unwrap(), 0, "server must close after framing loss");
    server.shutdown();
}

#[test]
fn stats_endpoint_reports_attention_savings() {
    let server = loopback_server(flat_snapshot(1.0), 256, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let mut gen = SynthDigits::new(77);
    for i in 0..50 {
        let digit = if i % 2 == 0 { 2u8 } else { 3u8 };
        match client.score(gen.render(digit)).unwrap() {
            Response::Score { features_evaluated, .. } => {
                assert!(features_evaluated < DIM, "inky image under flat weights exits early")
            }
            other => panic!("expected score, got {other:?}"),
        }
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.served, 50);
    assert!(stats.early_exit_rate > 0.9, "got {}", stats.early_exit_rate);
    assert!(stats.avg_features < DIM as f64);
    assert!(
        stats.features_p50 < DIM as u64,
        "histogram p50 {} must sit below full evaluation",
        stats.features_p50
    );
    assert!(stats.features_p99 >= stats.features_p50);
    assert!(stats.uptime_s > 0.0);
    server.shutdown();
}

/// Synthetic separable stream in a small dimension: label = sign(a+b)
/// with the two active coordinates cycling over a fixed support
/// (mirrors the online-trainer unit tests, but driven end-to-end over
/// the wire here). Indices are strictly increasing per example.
fn learn_stream(n: usize, seed: u64) -> Vec<(Vec<u32>, Vec<f64>, f64)> {
    let mut s = seed.wrapping_mul(2).wrapping_add(1);
    let mut next = move || {
        // SplitMix64-style scramble, plenty for test data.
        s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = s;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        (z ^ (z >> 31)) as f64 / u64::MAX as f64
    };
    (0..n)
        .map(|i| {
            let a = next() * 2.0 - 1.0;
            let b = next() * 2.0 - 1.0;
            let y = if a + b >= 0.0 { 1.0 } else { -1.0 };
            (vec![(i % 3) as u32, 3 + (i % 5) as u32], vec![a, b], y)
        })
        .collect()
}

#[test]
fn learn_over_the_wire_converges_and_publishes_generations() {
    const LDIM: usize = 16;
    let zero = ModelSnapshot {
        weights: vec![0.0; LDIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    };
    let frozen = ModelSnapshot { weights: vec![1.0; LDIM], ..zero.clone() };
    let trainer_cfg = TrainerWireConfig {
        queue: 4096, // outsizes the stream: nothing sheds
        publish_every_updates: 1,
        publish_every_ms: 0, // count-only cadence: deterministic publishes
        lambda: 1e-2,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::WeightSampled,
        seed: 11,
        ..Default::default()
    };
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        queue: 256,
        trainer: Some(trainer_cfg.clone()),
        ..Default::default()
    };
    let server = TcpServer::serve_models(
        &cfg,
        vec![("default".into(), zero.into()), ("frozen".into(), frozen.into())],
    )
    .expect("bind loopback");
    let addr = server.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    assert_eq!(client.negotiate().unwrap(), 7, "server grants v7");

    // Offline reference: the exact learner the wire trainer builds, fed
    // the same sequence — the server's counters must land on these.
    let examples = learn_stream(400, 5);
    let mut offline = build_wire_pegasos(&trainer_cfg, LDIM);
    let (mut offline_updates, mut offline_features) = (0u64, 0u64);
    for (idx, val, y) in &examples {
        let x = Features::Sparse { idx: idx.clone(), val: val.clone() }.densify(LDIM);
        let info = offline.process(&x, *y);
        offline_features += info.evaluated as u64;
        if info.updated {
            offline_updates += 1;
        }
    }

    // First example rides the JSON learn op, the rest the LEARN_SPARSE
    // frame: the trainer sees one identical sequence either way.
    let mut last_seen = 0u64;
    for (i, (idx, val, y)) in examples.iter().enumerate() {
        let label: i8 = if *y > 0.0 { 1 } else { -1 };
        let features = Features::Sparse { idx: idx.clone(), val: val.clone() };
        let resp = if i == 0 {
            client.learn(None, label, features).unwrap()
        } else {
            client.learn_sparse(0, label, idx.clone(), val.clone()).unwrap()
        };
        match resp {
            Response::Learned { seen, .. } => {
                assert!(seen > last_seen, "accepted-example count must increase");
                last_seen = seen;
            }
            other => panic!("learn got {other:?}"),
        }
    }
    assert_eq!(last_seen, examples.len() as u64, "queue outsizes the stream: no sheds");

    // Wait for the trainer to drain the queue; once it has, same seed ⇒
    // the same update count and attention spend as the offline run.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let shard = loop {
        let stats = client.stats().unwrap();
        let m = stats.models.iter().find(|m| m.name == "default").expect("default shard").clone();
        if m.learn_updates >= offline_updates {
            break m;
        }
        assert!(std::time::Instant::now() < deadline, "trainer never drained: {m:?}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    };
    assert!(shard.trainer, "stats must report the attached trainer");
    assert_eq!(shard.learn_examples, examples.len() as u64);
    assert_eq!(shard.learn_updates, offline_updates, "same seed ⇒ same update sequence");
    assert_eq!(shard.learn_features, offline_features, "same seed ⇒ same attention spend");
    assert_eq!(shard.learn_sheds, 0);
    assert!(shard.learn_publishes > 0, "cadence publishes must have landed");
    assert_eq!(
        u64::from(shard.gen),
        1 + shard.learn_publishes,
        "every publish lands as exactly one hub generation"
    );

    // The published model classifies fresh draws far above chance — the
    // shard started all-zero (score 0 for everything), so this is the
    // served error dropping, not the initial snapshot shining through.
    let probes = learn_stream(200, 77);
    let mut agree = 0;
    for (idx, val, y) in &probes {
        match client.score_sparse(idx.clone(), val.clone(), 0).unwrap() {
            Response::Score { score, .. } => {
                if (score >= 0.0) == (*y >= 0.0) {
                    agree += 1;
                }
            }
            other => panic!("probe got {other:?}"),
        }
    }
    assert!(agree >= 150, "served error stuck above threshold: {agree}/200 correct");

    // Other shards are untouched: no examples, no new generation.
    let stats = client.stats().unwrap();
    let frozen_stats = stats.models.iter().find(|m| m.name == "frozen").unwrap();
    assert_eq!(frozen_stats.gen, 1, "learning must not leak across shards");
    assert_eq!(frozen_stats.learn_examples, 0);
    server.shutdown();
}

#[test]
fn learn_floods_shed_explicitly_at_queue_saturation() {
    // One-slot learn queue, publish on every update: the trainer drains
    // as slowly as it ever will, so a response-free burst must shed.
    let trainer_cfg = TrainerWireConfig {
        queue: 1,
        publish_every_updates: 1,
        publish_every_ms: 0,
        seed: 3,
        ..Default::default()
    };
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        queue: 64,
        trainer: Some(trainer_cfg),
        ..Default::default()
    };
    let server = TcpServer::serve_models(
        &cfg,
        vec![("default".into(), flat_snapshot(0.0).into())],
    )
    .expect("bind loopback");
    let addr = server.local_addr().to_string();

    // Raw socket: negotiate v4 by hand, then burst LEARN_SPARSE frames
    // without reading a single response.
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    {
        let mut s = &stream;
        s.write_all(b"{\"op\":\"hello\",\"proto\":4}\n").unwrap();
    }
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(matches!(
        Response::parse(line.trim()).unwrap(),
        Response::Hello { proto: 4, .. }
    ));

    const BURST: usize = 200;
    let idx: Vec<u32> = (0..64).collect();
    let val = vec![0.5f64; 64];
    let mut burst = Vec::new();
    for i in 0..BURST {
        Frame::put_learn_sparse(&mut burst, 0, if i % 2 == 0 { 1 } else { -1 }, &idx, &val);
    }
    {
        let mut s = &stream;
        s.write_all(&burst).unwrap();
    }
    let (mut acks, mut sheds) = (0u64, 0u64);
    for _ in 0..BURST {
        match Frame::read_from(&mut reader, 1 << 20).unwrap() {
            Frame::LearnAck { .. } => acks += 1,
            Frame::Error { code, retryable, .. } => {
                assert_eq!(code, ErrorCode::Overloaded);
                assert!(retryable, "a shed must invite a retry");
                sheds += 1;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(acks + sheds, BURST as u64, "every burst frame gets an explicit answer");
    assert!(acks > 0, "the queue admits work");
    assert!(sheds > 0, "a one-slot queue under a {BURST}-frame burst must shed");
    drop(reader);
    drop(stream);

    // The server survives the flood, and the shed/accept split shows up
    // in both the trainer counters and the server-wide overload count.
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.overloaded, sheds);
    let shard = stats.models.iter().find(|m| m.name == "default").unwrap();
    assert_eq!(shard.learn_sheds, sheds);
    assert_eq!(shard.learn_examples, acks);
    server.shutdown();
}

#[test]
fn mixed_learn_and_score_load_shares_the_wire() {
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        queue: 4096,
        trainer: Some(TrainerWireConfig { seed: 21, ..Default::default() }),
        ..Default::default()
    };
    let server = TcpServer::serve_models(
        &cfg,
        vec![
            ("default".into(), trained_snapshot().into()),
            ("frozen".into(), flat_snapshot(1.0).into()),
        ],
    )
    .expect("bind loopback");
    let addr = server.local_addr().to_string();

    // Interleaved learn + score on the same connections (even sequence
    // numbers learn, odd score), against the default shard.
    let report = loadgen::run(&LoadGenConfig {
        addr: addr.clone(),
        connections: 2,
        requests: 400,
        pipeline: 8,
        hard_fraction: 0.5,
        mode: ClientMode::Mixed,
        sparse_eps: 0.05,
        seed: 31,
        ..Default::default()
    })
    .expect("loadgen");
    assert_eq!(report.sent, 400);
    assert_eq!(
        report.answered + report.learned + report.overloaded,
        400,
        "every mixed request gets a response: scored, learn-acked, or shed"
    );
    assert_eq!(report.errors, 0);
    assert!(report.learned > 0, "the learn half must be acked");
    assert!(report.answered > 0, "the score half must be answered");
    assert!(
        report.avg_features() < DIM as f64,
        "scoring keeps its attentive savings under concurrent learning, avg {}",
        report.avg_features()
    );

    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    let shard = stats.models.iter().find(|m| m.name == "default").unwrap();
    assert!(shard.trainer);
    assert!(shard.learn_examples > 0);
    let frozen_stats = stats.models.iter().find(|m| m.name == "frozen").unwrap();
    assert_eq!(frozen_stats.gen, 1, "no cross-shard publishes");
    assert_eq!(frozen_stats.learn_examples, 0);
    server.shutdown();
}

#[test]
fn deadline_expired_in_queue_sheds_at_dequeue() {
    // Zero weights never clear the boundary, so every example walks its
    // full support — a pending-cap's worth of 64×784 batches is several
    // milliseconds of worker backlog, far past a 1 ms deadline.
    let snapshot = ModelSnapshot {
        weights: vec![0.0; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    };
    let server = loopback_server(snapshot, 256, 1);
    let addr = server.local_addr().to_string();

    // Raw v7 socket: flood legacy (deadline-free) SCORE_BATCH frames
    // without reading a response, then one bulk-lane single carrying a
    // 1 ms deadline. The per-connection pending cap (64) keeps that
    // many batches in flight, so the single is admitted behind a full
    // cap of bulk work and must be expired by the time it is dequeued.
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    {
        let mut s = &stream;
        s.write_all(b"{\"op\":\"hello\",\"proto\":7}\n").unwrap();
    }
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(matches!(Response::parse(line.trim()).unwrap(), Response::Hello { proto: 7, .. }));

    const FLOOD: usize = 80;
    const PER_BATCH: usize = 64;
    let idx: Vec<u32> = (0..DIM as u32).collect();
    let val = vec![1.0f64; DIM];
    let mut batch = Vec::new();
    {
        let mut enc = Frame::begin_score_batch(&mut batch, 0, 0);
        for _ in 0..PER_BATCH {
            enc.push_example(&idx, &val);
        }
        enc.finish();
    }
    for _ in 0..FLOOD {
        let mut s = &stream;
        s.write_all(&batch).unwrap();
    }
    let mut single = Vec::new();
    Frame::put_sparse_ex(&mut single, 0, 0, 1, LANE_BULK, &[5], &[1.0]);
    {
        let mut s = &stream;
        s.write_all(&single).unwrap();
    }

    // The JSON twin on its own connection: same 1 ms deadline, same
    // bulk-lane override, admitted behind the same in-flight backlog.
    let mut json = Client::connect(&addr).unwrap();
    let shed = json
        .call(&Request::Score {
            id: None,
            model: None,
            features: Features::Sparse { idx: vec![5], val: vec![1.0] },
            deadline_ms: Some(1),
            priority: Some(Lane::Bulk),
        })
        .unwrap();
    assert!(shed.is_deadline_exceeded(), "JSON deadline shed, got {shed:?}");
    match shed {
        Response::Error { retryable, .. } => assert!(retryable, "a shed must invite a retry"),
        _ => unreachable!(),
    }

    // Drain the raw socket: every deadline-free batch answered in full,
    // plus exactly one DEADLINE_EXCEEDED frame for the expired single.
    let (mut rows_ok, mut deadline_errs) = (0usize, 0usize);
    for _ in 0..FLOOD + 1 {
        match Frame::read_from(&mut reader, 1 << 20).unwrap() {
            Frame::ScoreBatchResp { results, .. } => {
                assert_eq!(results.len(), PER_BATCH);
                assert!(results.iter().all(|r| r.status == BATCH_STATUS_OK));
                rows_ok += results.len();
            }
            Frame::Error { code, retryable, .. } => {
                assert_eq!(code, ErrorCode::DeadlineExceeded);
                assert!(retryable);
                deadline_errs += 1;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(rows_ok, FLOOD * PER_BATCH, "deadline-free bulk is never shed");
    assert_eq!(deadline_errs, 1);

    // A deadline with headroom is a no-op, and `stats` holds exactly
    // the two sheds.
    let mut control = Client::connect(&addr).unwrap();
    control.negotiate().unwrap();
    match control.score_sparse_ex(0, 0, 60_000, LANE_DEFAULT, &[5], &[1.0]).unwrap() {
        Response::Score { degraded, .. } => assert!(!degraded, "no brownout on this server"),
        other => panic!("headroom single got {other:?}"),
    }
    let stats = control.stats().unwrap();
    assert_eq!(stats.deadline_sheds, 2, "one binary + one JSON shed");
    assert_eq!(stats.degraded_responses, 0);
    assert_eq!(stats.tier_transitions, 0, "brownout disabled: the tier never moves");
    server.shutdown();
}

/// The brownout acceptance run: twin servers under the same
/// over-capacity single-stream load, one with an aggressive brownout
/// controller and one without. The brownout twin must answer
/// everything, climb at least one tier, flag degraded responses, and
/// spend measurably fewer features per answer than the plain twin.
#[test]
fn brownout_cuts_features_under_pressure_and_reports_tiers() {
    // Weights small enough that the untightened boundary is never (or
    // barely) cleared within a clean render's support — normal-tier
    // walks run the full support, keeping the single worker the
    // bottleneck — while the tier-1/2 boundaries (τ×0.25, τ×0.0625)
    // are cleared within tens of coordinates.
    let snapshot = ModelSnapshot {
        weights: vec![0.02; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    };
    let serve = |brownout: Option<BrownoutConfig>| {
        let cfg = ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 1,
            queue: 1024,
            brownout,
            ..Default::default()
        };
        TcpServer::serve(&cfg, snapshot.clone()).expect("bind loopback")
    };
    // In-flight (8 conns × 64 pipeline = 512) stays under the queue
    // bound: nothing sheds, every request is scored, and the queue
    // sits deep for the controller's whole sampling cadence. The huge
    // deadline never expires — it is there to switch the driver onto
    // the v7 EX frames whose responses carry the degraded flag.
    let load = |addr: String| {
        loadgen::run(&LoadGenConfig {
            addr,
            connections: 8,
            requests: 30_000,
            pipeline: 64,
            hard_fraction: 0.0,
            mode: ClientMode::V2Binary,
            sparse_eps: 0.05,
            deadline_ms: 60_000,
            seed: 97,
            ..Default::default()
        })
        .expect("loadgen")
    };

    let plain = serve(None);
    let plain_addr = plain.local_addr().to_string();
    let p = load(plain_addr.clone());
    let mut control = Client::connect(&plain_addr).unwrap();
    let p_stats = control.stats().unwrap();
    plain.shutdown();
    assert_eq!(p.sent, 30_000);
    assert_eq!(p.answered, 30_000, "under-queue load: every request scored");
    assert_eq!(p.errors, 0);
    assert_eq!(p.degraded, 0, "no brownout, no degraded answers");
    assert_eq!(p_stats.brownout_tier, 0);
    assert_eq!(p_stats.tier_transitions, 0);
    assert_eq!(p_stats.degraded_responses, 0);

    let browned = serve(Some(BrownoutConfig {
        tighten: 0.25,
        enter: 0.05,
        exit: 0.02,
        dwell_ms: 0,
        sample_ms: 1,
        latency_target_us: 0,
    }));
    let brown_addr = browned.local_addr().to_string();
    let q = load(brown_addr.clone());
    let mut control = Client::connect(&brown_addr).unwrap();
    let q_stats = control.stats().unwrap();
    browned.shutdown();
    assert_eq!(q.sent, 30_000);
    assert_eq!(q.answered, 30_000, "brownout degrades, it must not drop");
    assert_eq!(q.errors, 0);
    assert!(q.degraded > 0, "a deep queue must produce brown-tier answers");
    assert!(q_stats.tier_transitions >= 1, "the controller must have moved");
    assert_eq!(q_stats.degraded_responses, q.degraded, "server and client agree");
    assert!(
        q.avg_features() < 0.8 * p.avg_features(),
        "brown tiers must cut the mean attention spend: {} vs plain {}",
        q.avg_features(),
        p.avg_features()
    );
}

/// Brownout disabled — and brownout enabled but never pressured — are
/// bit-identical to each other over the wire: the tier-0 path reads
/// the same untightened table, so enabling the controller costs
/// nothing until pressure actually arrives.
#[test]
fn brownout_disabled_and_idle_controller_are_bit_identical() {
    let snapshot = ModelSnapshot {
        weights: vec![0.05; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    };
    let serve = |brownout: Option<BrownoutConfig>| {
        let cfg = ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 1,
            queue: 256,
            brownout,
            ..Default::default()
        };
        TcpServer::serve(&cfg, snapshot.clone()).expect("bind loopback")
    };
    // Thresholds no sequential single-connection stream can reach:
    // the controller runs but the tier never leaves `normal`.
    let inert = BrownoutConfig {
        tighten: 0.5,
        enter: 0.99,
        exit: 0.5,
        dwell_ms: 10_000,
        sample_ms: 50,
        latency_target_us: 0,
    };

    let mut digits = SynthDigits::new(53);
    let examples: Vec<(Vec<u32>, Vec<f64>)> = (0..12)
        .map(|i| {
            let dense = digits.render(if i % 2 == 0 { 2 } else { 3 });
            let (mut idx, mut val) = (Vec::new(), Vec::new());
            Features::sparsify_into(&dense, 0.05, &mut idx, &mut val);
            (idx, val)
        })
        .collect();

    let score_all = |server: &TcpServer| -> Vec<(u64, usize, bool)> {
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
        assert_eq!(client.negotiate().unwrap(), 7);
        examples
            .iter()
            .map(|(idx, val)| {
                match client.score_sparse2(0, idx.clone(), val.clone(), 0).unwrap() {
                    Response::Score { score, features_evaluated, degraded, .. } => {
                        (score.to_bits(), features_evaluated, degraded)
                    }
                    other => panic!("single got {other:?}"),
                }
            })
            .collect()
    };

    let off = serve(None);
    let rows_off = score_all(&off);
    off.shutdown();
    let on = serve(Some(inert));
    let rows_on = score_all(&on);
    let mut control = Client::connect(&on.local_addr().to_string()).unwrap();
    let stats = control.stats().unwrap();
    on.shutdown();

    assert_eq!(rows_off, rows_on, "idle controller must not perturb a single bit");
    assert!(rows_off.iter().all(|(_, _, degraded)| !degraded));
    assert_eq!(stats.degraded_responses, 0);
    assert_eq!(stats.tier_transitions, 0);
}

#[test]
fn batcher_flushes_at_count_and_drains_over_the_wire() {
    let server = loopback_server(flat_snapshot(1.0), 256, 1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    client.negotiate().unwrap();

    // Count trigger: k = 3 with a window too wide to ever fire.
    {
        let mut b = client.batcher(0, 0, 3, 60_000_000).unwrap();
        assert!(b.push(vec![10], vec![0.9]).unwrap().is_none());
        assert!(b.push(vec![20], vec![0.8]).unwrap().is_none());
        assert_eq!(b.pending(), 2);
        let rows = b.push(vec![30], vec![0.7]).unwrap().expect("third push fills the batch");
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| r.status == BATCH_STATUS_OK && r.score > 0.0));
        assert_eq!(b.pending(), 0, "a flush rearms the window");

        // End-of-stream drain: whatever is buffered goes out as one
        // final short batch.
        assert!(b.push(vec![40], vec![0.6]).unwrap().is_none());
        assert!(b.push(vec![50], vec![0.5]).unwrap().is_none());
        let rows = b.flush().unwrap();
        assert_eq!(rows.len(), 2);
        assert!(b.flush().unwrap().is_empty(), "an empty drain never touches the wire");
    }

    // Time trigger: a 1 µs window with a distant count trigger — the
    // second push lands long after the window and must flush both.
    {
        let mut b = client.batcher(0, 0, 100, 1).unwrap();
        assert!(b.push(vec![10], vec![0.9]).unwrap().is_none(), "first push opens the window");
        std::thread::sleep(std::time::Duration::from_millis(5));
        let rows = b.push(vec![20], vec![0.8]).unwrap().expect("window expired");
        assert_eq!(rows.len(), 2);

        // flush_if_due: the drain for callers polling between pushes.
        assert!(b.push(vec![30], vec![0.7]).unwrap().is_none());
        std::thread::sleep(std::time::Duration::from_millis(5));
        let rows = b.flush_if_due().unwrap().expect("window expired while idle");
        assert_eq!(rows.len(), 1);
    }
    server.shutdown();
}

/// The CI overload smoke (both I/O backends): windowed open-loop load
/// far past a single worker's capacity, every request carrying a 1 ms
/// deadline, against a brownout-enabled server. Gates: nothing goes
/// unanswered, deadlines actually shed, and the controller visibly
/// moves at least one tier.
fn overload_smoke_with_deadlines_on(backend: IoBackend) {
    // Zero weights: no early exit ever, so per-request service cost is
    // the full support walk and does not shrink as tiers climb — the
    // queue stays saturated for the whole run.
    let snapshot = ModelSnapshot {
        weights: vec![0.0; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Permuted,
    };
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        queue: 8192,
        io_backend: backend,
        brownout: Some(BrownoutConfig {
            tighten: 0.5,
            enter: 0.05,
            exit: 0.02,
            dwell_ms: 0,
            sample_ms: 1,
            latency_target_us: 0,
        }),
        ..Default::default()
    };
    let server = TcpServer::serve(&cfg, snapshot).expect("bind loopback");
    let addr = server.local_addr().to_string();

    // 128 sockets × 64-request windows = 8192 in flight per sweep —
    // hours of queue wait in 1 ms-deadline terms. Expired requests are
    // shed at dequeue in microseconds, so the run still drains fast.
    let report = loadgen::run(&LoadGenConfig {
        addr: addr.clone(),
        connections: 128,
        requests: 16_384,
        pipeline: 64,
        hard_fraction: 1.0,
        mode: ClientMode::V2Binary,
        sparse_eps: 0.05,
        deadline_ms: 1,
        seed: 13,
        open_loop: true,
        ..Default::default()
    })
    .expect("loadgen");

    assert_eq!(report.sent, 16_384, "backend {backend:?}");
    assert_eq!(report.errors, 0, "backend {backend:?}: no protocol errors under overload");
    assert_eq!(
        report.answered + report.overloaded + report.deadline_sheds,
        report.sent,
        "backend {backend:?}: zero unanswered requests"
    );
    assert!(
        report.deadline_sheds > 0,
        "backend {backend:?}: a saturated queue must expire 1 ms deadlines"
    );

    let mut control = Client::connect(&addr).unwrap();
    let stats = control.stats().unwrap();
    assert_eq!(stats.deadline_sheds, report.deadline_sheds, "backend {backend:?}");
    assert!(
        stats.tier_transitions >= 1,
        "backend {backend:?}: sustained pressure must move the tier"
    );
    server.shutdown();
}

#[test]
fn overload_smoke_with_deadlines() {
    overload_smoke_with_deadlines_on(IoBackend::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn overload_smoke_with_deadlines_on_event_loop() {
    overload_smoke_with_deadlines_on(IoBackend::EventLoop);
}
