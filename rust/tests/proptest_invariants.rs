//! Randomized property tests over the STST/margin/coordinator invariants
//! (DESIGN.md §5), using the in-tree `util::check` harness.

use attentive::data::stream::ShuffledIndices;
use attentive::margin::evaluator::{BlockedEvaluator, ScalarEvaluator};
use attentive::margin::policy::{CoordinatePolicy, OrderGenerator};
use attentive::margin::walker::WalkOutcome;
use attentive::stst::boundary::{Boundary, ConstantBoundary, StopContext};
use attentive::stst::brownian;
use attentive::stst::variance::OnlineVariance;
use attentive::util::check::{forall, Config};
use attentive::util::rng::Rng64;

/// (a) Boundary monotonicity: τ is decreasing in δ, increasing in var/θ.
#[test]
fn prop_boundary_monotone() {
    forall(
        Config { cases: 300, seed: 0xB0 },
        |rng, _| {
            (
                rng.range_f64(0.01, 0.5),  // delta
                rng.range_f64(0.0, 3.0),   // theta
                rng.range_f64(0.01, 500.0), // var
            )
        },
        |&(delta, theta, var)| {
            let tau = brownian::constant_boundary_level(delta, theta, var);
            let tau_lax = brownian::constant_boundary_level((delta * 1.5).min(0.99), theta, var);
            let tau_var = brownian::constant_boundary_level(delta, theta, var * 2.0);
            let tau_theta = brownian::constant_boundary_level(delta, theta + 0.5, var);
            if tau_lax > tau + 1e-12 {
                return Err(format!("tau not decreasing in delta: {tau} -> {tau_lax}"));
            }
            if tau_var < tau {
                return Err("tau not increasing in var".into());
            }
            if tau_theta < tau {
                return Err("tau not increasing in theta".into());
            }
            // And it always inverts the crossing probability exactly.
            let p = brownian::bridge_crossing_prob(tau, theta, var);
            if (p - delta).abs() > 1e-6 {
                return Err(format!("inversion broken: p={p} delta={delta}"));
            }
            Ok(())
        },
    );
}

/// (b) As δ→0 the walker stops late or never on any bounded example.
#[test]
fn prop_tiny_delta_rarely_stops() {
    forall(
        Config { cases: 100, seed: 0xB1 },
        |rng, size| {
            let n = 16 + (size * 200.0) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            (w, x)
        },
        |(w, x)| {
            let n = w.len();
            let order: Vec<usize> = (0..n).collect();
            // Huge variance + tiny delta => boundary far above any
            // achievable partial sum of bounded products.
            let var = (n as f64) * 4.0;
            let res = ScalarEvaluator::new().evaluate(
                w,
                x,
                1.0,
                &order,
                0.0,
                var,
                &ConstantBoundary::new(1e-9),
            );
            if res.outcome == WalkOutcome::EarlyStopped {
                return Err(format!("stopped at {} with delta=1e-9", res.evaluated));
            }
            Ok(())
        },
    );
}

/// (c) Blocked evaluator at block=1 is exactly the scalar evaluator, and
/// at any block size stops at the first boundary-multiple ≥ scalar stop.
#[test]
fn prop_blocked_matches_scalar() {
    forall(
        Config { cases: 150, seed: 0xB2 },
        |rng, size| {
            let blocks = 1 + (size * 12.0) as usize;
            let block = 1 << rng.range_usize(0, 4); // 1,2,4,8,16
            let n = block * blocks.max(2);
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let y = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            let var = rng.range_f64(0.001, 2.0);
            (block, w, x, y, var)
        },
        |(block, w, x, y, var)| {
            let n = w.len();
            let order: Vec<usize> = (0..n).collect();
            let b = ConstantBoundary::new(0.1);
            let scalar = ScalarEvaluator::new().evaluate(w, x, *y, &order, 1.0, *var, &b);
            let blocked =
                BlockedEvaluator::new(*block).evaluate(w, x, *y, &order, 1.0, *var, &b);
            if *block == 1 {
                if scalar.evaluated != blocked.evaluated
                    || scalar.outcome != blocked.outcome
                {
                    return Err("block=1 must equal scalar".into());
                }
                return Ok(());
            }
            if blocked.outcome == WalkOutcome::EarlyStopped {
                if blocked.evaluated % block != 0 {
                    return Err("blocked stop not at a block boundary".into());
                }
                if blocked.evaluated < scalar.evaluated.min(n) && scalar.outcome == WalkOutcome::EarlyStopped && blocked.evaluated + block <= scalar.evaluated {
                    return Err(format!(
                        "blocked stopped {} more than a block before scalar {}",
                        blocked.evaluated, scalar.evaluated
                    ));
                }
            }
            // Full margins agree when both complete.
            if blocked.outcome == WalkOutcome::Completed
                && scalar.outcome == WalkOutcome::Completed
                && (blocked.partial_margin - scalar.partial_margin).abs() > 1e-9
            {
                return Err("completed margins disagree".into());
            }
            Ok(())
        },
    );
}

/// (d) Stream shuffler conserves examples (no loss, no duplication).
#[test]
fn prop_shuffler_conserves() {
    forall(
        Config { cases: 200, seed: 0xB3 },
        |rng, size| {
            let len = (size * 500.0) as usize + 1;
            (len, rng.next_u64(), rng.below(5) as u64)
        },
        |&(len, seed, epoch)| {
            let p = ShuffledIndices::new(len, seed).epoch(epoch);
            let mut seen = vec![false; len];
            for &i in &p {
                if i >= len || seen[i] {
                    return Err(format!("index {i} out of range or duplicated"));
                }
                seen[i] = true;
            }
            if !seen.iter().all(|&s| s) {
                return Err("permutation dropped indices".into());
            }
            Ok(())
        },
    );
}

/// (e) Variance estimation is permutation-invariant and matches two-pass.
#[test]
fn prop_variance_permutation_invariant() {
    forall(
        Config { cases: 150, seed: 0xB4 },
        |rng, size| {
            let n = 2 + (size * 60.0) as usize;
            let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let seed = rng.next_u64();
            (xs, seed)
        },
        |(xs, seed)| {
            let mut fwd = OnlineVariance::new();
            xs.iter().for_each(|&x| fwd.update(x));
            let mut shuffled = xs.clone();
            Rng64::seed_from_u64(*seed).shuffle(&mut shuffled);
            let mut per = OnlineVariance::new();
            shuffled.iter().for_each(|&x| per.update(x));
            if (fwd.variance() - per.variance()).abs() > 1e-9 {
                return Err("variance depends on order".into());
            }
            let n = xs.len() as f64;
            let mean = xs.iter().sum::<f64>() / n;
            let tp = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
            if (fwd.variance() - tp).abs() > 1e-8 {
                return Err(format!("welford {} vs two-pass {tp}", fwd.variance()));
            }
            Ok(())
        },
    );
}

/// (f) Policy orders are always valid coordinate indices, and permutation
/// policies touch every coordinate exactly once.
#[test]
fn prop_policy_orders_valid() {
    forall(
        Config { cases: 120, seed: 0xB5 },
        |rng, size| {
            let n = 1 + (size * 100.0) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            (w, rng.next_u64())
        },
        |(w, seed)| {
            for policy in CoordinatePolicy::ALL {
                let mut g = OrderGenerator::new(policy, *seed);
                let order = g.order(w).to_vec();
                if order.len() != w.len() {
                    return Err(format!("{policy:?}: wrong order length"));
                }
                if order.iter().any(|&i| i >= w.len()) {
                    return Err(format!("{policy:?}: out-of-range index"));
                }
                if !matches!(policy, CoordinatePolicy::WeightSampled) {
                    let mut seen = vec![false; w.len()];
                    for &i in &order {
                        if seen[i] {
                            return Err(format!("{policy:?}: duplicated index {i}"));
                        }
                        seen[i] = true;
                    }
                }
            }
            Ok(())
        },
    );
}

/// (g) Budget boundaries never exceed their budget regardless of inputs.
#[test]
fn prop_budget_respected() {
    forall(
        Config { cases: 150, seed: 0xB6 },
        |rng, size| {
            let n = 4 + (size * 300.0) as usize;
            let k = 1 + rng.below(n);
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            (k, w, x)
        },
        |(k, w, x)| {
            let order: Vec<usize> = (0..w.len()).collect();
            let b = attentive::stst::boundary::BudgetedBoundary::new(*k);
            let res = ScalarEvaluator::new().evaluate(w, x, 1.0, &order, 1.0, 1.0, &b);
            if res.evaluated != (*k).min(w.len()) {
                return Err(format!("budget {k}, evaluated {}", res.evaluated));
            }
            Ok(())
        },
    );
}

/// (h2) Curved boundary is monotone decreasing in progress and meets θ
/// at the end (the curtailed envelope shape).
#[test]
fn prop_curved_boundary_monotone_decreasing() {
    use attentive::stst::boundary::CurvedBoundary;
    forall(
        Config { cases: 200, seed: 0xB8 },
        |rng, _| {
            (
                rng.range_f64(0.01, 0.5),
                rng.range_f64(0.0, 2.0),
                rng.range_f64(0.1, 200.0),
                4 + rng.below(1000),
            )
        },
        |&(delta, theta, var, n)| {
            let b = CurvedBoundary::new(delta);
            let mut prev = f64::INFINITY;
            for i in [1usize, n / 4, n / 2, 3 * n / 4, n - 1] {
                let l = b.level(&StopContext { evaluated: i, total: n, theta, var_sn: var });
                if l > prev + 1e-9 {
                    return Err(format!("curved level rose at i={i}: {prev} -> {l}"));
                }
                if l < theta - 1e-9 {
                    return Err(format!("curved level {l} fell below theta {theta}"));
                }
                prev = l;
            }
            Ok(())
        },
    );
}

/// (i) Two-sided prediction walks stop symmetrically: negating the input
/// flips the score's sign but not the stopping step.
#[test]
fn prop_predictor_sign_symmetry() {
    use attentive::learner::predictor::EarlyStopPredictor;
    forall(
        Config { cases: 150, seed: 0xB9 },
        |rng, size| {
            let n = 8 + (size * 200.0) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let var = rng.range_f64(0.01, 5.0);
            (w, x, var)
        },
        |(w, x, var)| {
            let order: Vec<usize> = (0..w.len()).collect();
            let b = ConstantBoundary::new(0.1);
            let p = EarlyStopPredictor::new(&b);
            let (s1, k1) = p.predict(w, x, &order, *var);
            let neg: Vec<f64> = x.iter().map(|v| -v).collect();
            let (s2, k2) = p.predict(w, &neg, &order, *var);
            if (s1 + s2).abs() > 1e-9 {
                return Err(format!("scores not antisymmetric: {s1} vs {s2}"));
            }
            if k1 != k2 {
                return Err(format!("stopping steps differ: {k1} vs {k2}"));
            }
            Ok(())
        },
    );
}

/// (j) Lazy walk and materialized-order walk agree exactly for
/// deterministic (weight-independent-RNG) policies given the same seed.
#[test]
fn prop_lazy_walk_matches_slice_walk_sequential() {
    use attentive::margin::walker::Walker;
    forall(
        Config { cases: 150, seed: 0xBA },
        |rng, size| {
            let n = 4 + (size * 300.0) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let y = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            let var = rng.range_f64(0.01, 3.0);
            (w, x, y, var)
        },
        |(w, x, y, var)| {
            let n = w.len();
            let order: Vec<usize> = (0..n).collect();
            let b = ConstantBoundary::new(0.1);
            let walker = Walker::new();
            let slice_res = walker.walk(w, x, *y, &order, 1.0, *var, &b);
            let mut gen = OrderGenerator::new(CoordinatePolicy::Sequential, 0);
            gen.refresh(w);
            let mut visited = Vec::new();
            let lazy_res = walker.walk_lazy(w, x, *y, &mut gen, 1.0, *var, &b, &mut visited);
            if slice_res.evaluated != lazy_res.evaluated
                || slice_res.outcome != lazy_res.outcome
                || (slice_res.partial_margin - lazy_res.partial_margin).abs() > 1e-12
            {
                return Err(format!(
                    "lazy {:?}@{} vs slice {:?}@{}",
                    lazy_res.outcome, lazy_res.evaluated, slice_res.outcome, slice_res.evaluated
                ));
            }
            if visited.len() != lazy_res.evaluated {
                return Err("visited length != evaluated".into());
            }
            if visited.iter().enumerate().any(|(i, &j)| i != j) {
                return Err("sequential visit order wrong".into());
            }
            Ok(())
        },
    );
}

/// (h) Constant boundary level is independent of progress i (flatness).
#[test]
fn prop_constant_boundary_flat() {
    forall(
        Config { cases: 200, seed: 0xB7 },
        |rng, _| {
            (
                rng.range_f64(0.01, 0.9),
                rng.range_f64(0.0, 2.0),
                rng.range_f64(0.0, 100.0),
                rng.range_usize(1, 1000),
            )
        },
        |&(delta, theta, var, i)| {
            let b = ConstantBoundary::new(delta);
            let l1 = b.level(&StopContext { evaluated: 1, total: 1001, theta, var_sn: var });
            let li = b.level(&StopContext { evaluated: i, total: 1001, theta, var_sn: var });
            if (l1 - li).abs() > 1e-12 {
                return Err("constant boundary varies with i".into());
            }
            Ok(())
        },
    );
}

/// (i) Wire protocol v2: encode→decode is the identity for every
/// sparse score frame (random gen/support/values, including empty).
#[test]
fn prop_v2_frame_codec_round_trips() {
    use attentive::server::frame::Frame;

    forall(
        Config { cases: 300, seed: 0xB8 },
        |rng, size| {
            let nnz = (size * 300.0 * rng.f64()) as usize;
            // Strictly increasing u16 indices.
            let mut idx: Vec<u16> = Vec::with_capacity(nnz);
            let mut next = 0u32;
            for _ in 0..nnz {
                next += 1 + rng.below(8) as u32;
                if next > u16::MAX as u32 {
                    break;
                }
                idx.push(next as u16);
            }
            let val: Vec<f64> =
                (0..idx.len()).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            let gen = rng.next_u64() as u32;
            (gen, idx, val)
        },
        |(gen, idx, val)| {
            let frame = Frame::ScoreSparse { gen: *gen, idx: idx.clone(), val: val.clone() };
            let wire = frame.encode();
            let (back, used) = Frame::decode(&wire, 1 << 20)
                .map_err(|e| format!("decode failed: {e}"))?;
            if used != wire.len() {
                return Err(format!("consumed {used} of {} bytes", wire.len()));
            }
            if back != frame {
                return Err(format!("round trip mutated the frame: {back:?}"));
            }
            // Every strict prefix must fail to decode (truncation is
            // always detected, never a silent short parse).
            for cut in [0, 1, 3, wire.len().saturating_sub(1)] {
                if cut < wire.len() && Frame::decode(&wire[..cut], 1 << 20).is_ok() {
                    return Err(format!("truncated decode at {cut} bytes succeeded"));
                }
            }
            Ok(())
        },
    );
}

/// (j) The sparse scoring path is lossless: under the Full boundary
/// (no early exit) the sparse walk over the support must equal the
/// dense dot product of the densified vector, for every policy.
#[test]
fn prop_sparse_scoring_is_lossless() {
    use attentive::learner::predictor::EarlyStopPredictor;
    use attentive::stst::boundary::TrivialBoundary;

    forall(
        Config { cases: 200, seed: 0xB9 },
        |rng, size| {
            let dim = 8 + (size * 200.0) as usize;
            let w: Vec<f64> = (0..dim).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let nnz = rng.below(dim / 2 + 1);
            let mut idx: Vec<u32> = Vec::new();
            let mut next = 0usize;
            for _ in 0..nnz {
                next += 1 + rng.below(3);
                if next >= dim {
                    break;
                }
                idx.push(next as u32);
            }
            let val: Vec<f64> =
                (0..idx.len()).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let policy_seed = rng.next_u64();
            (w, idx, val, policy_seed)
        },
        |(w, idx, val, policy_seed)| {
            // The dense dot product equals the support sum by
            // construction (zeros contribute nothing) — that sum is the
            // lossless reference every policy's sparse walk must hit.
            let exact: f64 =
                idx.iter().zip(val.iter()).map(|(&i, &v)| w[i as usize] * v).sum();
            let predictor = EarlyStopPredictor::new(&TrivialBoundary);
            for policy in CoordinatePolicy::ALL {
                let mut orders = OrderGenerator::new(policy, *policy_seed);
                orders.refresh(w);
                let order = orders.next_sparse(w, idx).to_vec();
                if order.len() != idx.len() {
                    return Err(format!("{policy:?}: order len {} != nnz", order.len()));
                }
                let (score, evaluated) = predictor.predict_sparse(w, idx, val, &order, 4.0);
                if evaluated != idx.len() {
                    return Err(format!(
                        "{policy:?}: full boundary must walk the whole support, took {evaluated}"
                    ));
                }
                // Weight-sampled draws with replacement do not visit
                // each support coordinate exactly once, so exact-sum
                // equality only holds for the permutation policies.
                if policy != CoordinatePolicy::WeightSampled
                    && (score - exact).abs() > 1e-9 * (1.0 + exact.abs())
                {
                    return Err(format!("{policy:?}: sparse {score} != dense {exact}"));
                }
            }
            Ok(())
        },
    );
}
