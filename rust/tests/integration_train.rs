//! Integration: end-to-end training across the full native stack —
//! data generation → 1-vs-1 task → learners under all four boundaries →
//! trainer → metrics. This is the Figure 3 pipeline at reduced scale.

use attentive::config::{DataConfig, ExperimentConfig, LearnerKind};
use attentive::coordinator::scheduler::run_experiment;
use attentive::coordinator::trainer::{Trainer, TrainerConfig};
use attentive::data::synth::SynthDigits;
use attentive::data::task::BinaryTask;
use attentive::learner::attentive::attentive_pegasos;
use attentive::learner::budgeted::budgeted_pegasos;
use attentive::learner::pegasos::{Pegasos, PegasosConfig};
use attentive::margin::policy::CoordinatePolicy;
use attentive::stst::boundary::AnyBoundary;

fn small_cfg(boundary: AnyBoundary) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("it-{}", boundary.to_json().to_string_compact().len()),
        data: DataConfig::Synth { seed: 33, count: 2_000 },
        boundary,
        runs: 2,
        epochs: 2,
        eval_every: 0,
        lambda: 1e-2,
        ..ExperimentConfig::paper_default()
    }
}

#[test]
fn paper_trio_orders_correctly() {
    // Full / Attentive / Budgeted on the same task: attentive must match
    // full's accuracy (±5%) at a fraction of the features; budgeted gets
    // the attentive budget (the paper's protocol).
    let full = run_experiment(&small_cfg(AnyBoundary::Full)).unwrap();
    let att = run_experiment(&small_cfg(AnyBoundary::Constant {
        delta: 0.1,
        paper_literal: false,
    }))
    .unwrap();
    let k = att.avg_features.round().max(1.0) as usize;
    let mut bcfg = small_cfg(AnyBoundary::Budgeted { k });
    bcfg.policy = CoordinatePolicy::Permuted; // sorted+budgeted impossible
    let bud = run_experiment(&bcfg).unwrap();

    assert!(att.avg_features < full.avg_features / 2.0);
    assert!(att.final_test_error <= full.final_test_error + 0.05);
    assert!((bud.avg_features - k as f64).abs() < 1.0);
    // Early-stopped prediction: attentive beats budgeted (paper's right
    // subfigure claim).
    assert!(
        att.final_test_error_early <= bud.final_test_error_early + 0.02,
        "attentive early err {} vs budgeted {}",
        att.final_test_error_early,
        bud.final_test_error_early
    );
}

#[test]
fn all_learner_kinds_train_end_to_end() {
    for kind in [LearnerKind::Pegasos, LearnerKind::Perceptron, LearnerKind::PassiveAggressive] {
        let mut cfg = small_cfg(AnyBoundary::Constant { delta: 0.1, paper_literal: false });
        cfg.learner = kind;
        cfg.runs = 1;
        let out = run_experiment(&cfg).unwrap();
        assert!(
            out.final_test_error < 0.2,
            "{:?} error {} too high",
            kind,
            out.final_test_error
        );
        assert!(out.avg_features < 784.0);
    }
}

#[test]
fn delta_controls_the_computation_accuracy_tradeoff() {
    // Sweeping delta: higher delta = more aggressive stopping = fewer
    // features; error may rise slightly.
    let feats: Vec<f64> = [0.01, 0.1, 0.4]
        .iter()
        .map(|&d| {
            run_experiment(&small_cfg(AnyBoundary::Constant { delta: d, paper_literal: false }))
                .unwrap()
                .avg_features
        })
        .collect();
    assert!(
        feats[0] > feats[1] && feats[1] > feats[2],
        "features must fall with delta: {feats:?}"
    );
}

#[test]
fn curved_boundary_is_more_conservative_than_constant() {
    let curved =
        run_experiment(&small_cfg(AnyBoundary::Curved { delta: 0.1 })).unwrap();
    let constant = run_experiment(&small_cfg(AnyBoundary::Constant {
        delta: 0.1,
        paper_literal: false,
    }))
    .unwrap();
    assert!(
        curved.avg_features >= constant.avg_features,
        "curved {} should evaluate at least as many features as constant {}",
        curved.avg_features,
        constant.avg_features
    );
}

#[test]
fn multi_epoch_training_reduces_error() {
    let ds = SynthDigits::new(44).generate_classes(1_500, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).unwrap();
    let (train, test) = task.split(0.8);
    let one = {
        let mut l = Pegasos::full(train.dim(), PegasosConfig { lambda: 1e-3, ..Default::default() });
        Trainer::new(TrainerConfig { epochs: 1, eval_every: 0, curves: false, ..Default::default() })
            .fit_eval(&mut l, &train, Some(&test))
            .final_test_error
    };
    let five = {
        let mut l = Pegasos::full(train.dim(), PegasosConfig { lambda: 1e-3, ..Default::default() });
        Trainer::new(TrainerConfig { epochs: 5, eval_every: 0, curves: false, ..Default::default() })
            .fit_eval(&mut l, &train, Some(&test))
            .final_test_error
    };
    assert!(five <= one + 0.01, "5 epochs {five} vs 1 epoch {one}");
}

#[test]
fn budgeted_uses_attentive_average_protocol() {
    // The paper's protocol end-to-end: measure attentive's average, hand
    // it to budgeted as a fixed budget, check budgets respected per step.
    let ds = SynthDigits::new(55).generate_classes(600, &[2, 3]);
    let task = BinaryTask::one_vs_one(&ds, 2, 3).unwrap();
    let mut att = attentive_pegasos(task.dim(), 1e-2, 0.1);
    let r = Trainer::new(TrainerConfig { eval_every: 0, curves: false, ..Default::default() })
        .fit(&mut att, &task);
    let k = r.avg_features_per_example().round().max(1.0) as usize;
    assert!(k < 784, "attentive average {k} should be well under 784");
    let mut bud = budgeted_pegasos(task.dim(), 1e-2, k, CoordinatePolicy::Permuted, 0);
    let rb = Trainer::new(TrainerConfig { eval_every: 0, curves: false, ..Default::default() })
        .fit(&mut bud, &task);
    assert!((rb.avg_features_per_example() - k as f64).abs() < 1e-9);
}
