//! Transport edge cases, exercised against **both** I/O backends
//! (thread-per-connection and the epoll event loop): slowloris partial
//! frames, oversized-frame rejection mid-stream, idle-connection churn,
//! half-close handling, and the verbose-classify breakdown over every
//! wire form. Each scenario runs per backend so the two transports
//! cannot drift apart on edge semantics.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

use attentive::config::{IoBackend, ServerConfig};
use attentive::coordinator::service::{
    EnsembleSnapshot, Features, ModelSnapshot, ServingModel, VoterSnapshot,
};
use attentive::server::frame::{ErrorCode, Frame};
use attentive::server::loadgen::Client;
use attentive::server::protocol::{Request, Response};
use attentive::server::tcp::TcpServer;
use attentive::stst::boundary::AnyBoundary;

const DIM: usize = 784;

/// Flat binary snapshot: deterministic sign for inky digit imagery.
fn flat_snapshot(w: f64) -> ModelSnapshot {
    ModelSnapshot {
        weights: vec![w; DIM],
        var_sn: 4.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: attentive::margin::policy::CoordinatePolicy::Permuted,
    }
}

/// Flat deterministic 3-class ensemble (classes 0/1/2; positive input
/// → every voter votes its `pos` class → label 0).
fn flat_ensemble() -> EnsembleSnapshot {
    let classes = vec![0i64, 1, 2];
    let mut voters = Vec::new();
    for a in 0..classes.len() {
        for b in a + 1..classes.len() {
            voters.push(VoterSnapshot {
                pos: classes[a],
                neg: classes[b],
                weights: vec![1.0; DIM],
                var_sn: 4.0,
            });
        }
    }
    EnsembleSnapshot {
        classes,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: attentive::margin::policy::CoordinatePolicy::Permuted,
        voters,
    }
}

/// The backends this platform can run (the event loop needs epoll).
fn backends() -> Vec<IoBackend> {
    let mut all = vec![IoBackend::Threads];
    if cfg!(target_os = "linux") {
        all.push(IoBackend::EventLoop);
    }
    all
}

fn server_on(backend: IoBackend, models: Vec<(String, ServingModel)>) -> TcpServer {
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        io_backend: backend,
        event_threads: 2,
        workers: 2,
        queue: 4096,
        ..Default::default()
    };
    TcpServer::serve_models(&cfg, models).expect("bind loopback")
}

fn binary_server(backend: IoBackend) -> TcpServer {
    server_on(backend, vec![("default".into(), flat_snapshot(1.0).into())])
}

/// Slowloris: a valid request dripped one byte at a time — on a JSON
/// line and then on a binary frame whose header itself arrives
/// byte-by-byte. The server must buffer patiently and answer both.
#[test]
fn slowloris_partial_requests_are_buffered_not_dropped() {
    for backend in backends() {
        let server = binary_server(backend);
        let addr = server.local_addr().to_string();
        let stream = TcpStream::connect(&addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());

        // v1 line, one byte at a time.
        let line = Request::Score {
            id: Some(7),
            model: None,
            features: Features::Sparse { idx: vec![3, 40], val: vec![1.0, 1.0] },
            deadline_ms: None,
            priority: None,
        }
        .to_line();
        for &b in line.as_bytes() {
            (&stream).write_all(&[b]).unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        match Response::parse(reply.trim()).unwrap() {
            Response::Score { id, score, .. } => {
                assert_eq!(id, Some(7), "backend {backend:?}");
                assert!(score > 0.0);
            }
            other => panic!("{backend:?}: expected score, got {other:?}"),
        }

        // Upgrade to binary, then drip a sparse frame byte-by-byte —
        // including the 4-byte length prefix.
        (&stream).write_all(b"{\"op\":\"hello\",\"proto\":3}\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(
            matches!(Response::parse(reply.trim()).unwrap(), Response::Hello { proto: 3, .. }),
            "backend {backend:?}"
        );
        let wire = Frame::ScoreSparse { gen: 0, idx: vec![5, 9], val: vec![1.0, 1.0] }.encode();
        for &b in &wire {
            (&stream).write_all(&[b]).unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        match Frame::read_from(&mut reader, 1 << 20).unwrap() {
            Frame::Score { score, evaluated, .. } => {
                assert!(score > 0.0, "backend {backend:?}");
                assert!(evaluated <= 2);
            }
            other => panic!("{backend:?}: expected score frame, got {other:?}"),
        }
        drop(reader);
        drop(stream);
        server.shutdown();
    }
}

/// Oversized frame mid-stream: after successful binary traffic, a
/// length prefix beyond the server cap draws one `BAD_FRAME` error and
/// the connection closes — and the server keeps serving new clients.
#[test]
fn oversized_frame_mid_stream_errors_and_closes_only_that_connection() {
    for backend in backends() {
        let cfg = ServerConfig {
            listen: "127.0.0.1:0".into(),
            io_backend: backend,
            max_frame_bytes: 4096,
            ..Default::default()
        };
        let server =
            TcpServer::serve_models(&cfg, vec![("default".into(), flat_snapshot(1.0).into())])
                .expect("bind");
        let addr = server.local_addr().to_string();

        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        (&stream).write_all(b"{\"op\":\"hello\",\"proto\":2}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        // Healthy traffic first.
        (&stream)
            .write_all(&Frame::ScoreSparse { gen: 0, idx: vec![1], val: vec![1.0] }.encode())
            .unwrap();
        assert!(
            matches!(Frame::read_from(&mut reader, 1 << 20).unwrap(), Frame::Score { .. }),
            "backend {backend:?}"
        );
        // Now a prefix claiming 1 MiB against the 4 KiB cap.
        (&stream).write_all(&(1u32 << 20).to_le_bytes()).unwrap();
        match Frame::read_from(&mut reader, 1 << 20).unwrap() {
            Frame::Error { code, retryable, .. } => {
                assert_eq!(code, ErrorCode::BadFrame, "backend {backend:?}");
                assert!(!retryable);
            }
            other => panic!("{backend:?}: expected BadFrame, got {other:?}"),
        }
        let mut probe = [0u8; 1];
        assert_eq!(
            reader.read(&mut probe).unwrap_or(0),
            0,
            "{backend:?}: connection must close after framing loss"
        );
        // The server is unharmed: a fresh client still scores.
        let mut client = Client::connect(&addr).unwrap();
        assert!(matches!(
            client.score(vec![0.5; DIM]).unwrap(),
            Response::Score { .. }
        ));
        let stats = server.shutdown();
        assert!(stats.protocol_errors >= 1, "backend {backend:?}");
    }
}

/// Idle-connection churn: open a pile of connections, use only a few,
/// close them all; repeat. The server must neither shed nor leak. The
/// event loop takes the full 500; the thread backend gets a smaller
/// pile (it pays two threads per connection — that's the point of the
/// event loop).
#[test]
fn idle_connection_churn_neither_sheds_nor_leaks() {
    for backend in backends() {
        let pile = match backend {
            IoBackend::Threads => 50,
            IoBackend::EventLoop => 500,
        };
        let server = binary_server(backend);
        let addr = server.local_addr().to_string();
        for round in 0..2 {
            let mut idle = Vec::with_capacity(pile);
            for _ in 0..pile {
                idle.push(TcpStream::connect(&addr).unwrap());
            }
            // Use 10 of them; the rest just sit there.
            for stream in idle.iter().take(10) {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                (&*stream)
                    .write_all(
                        Request::Score {
                            id: None,
                            model: None,
                            features: Features::Sparse { idx: vec![9], val: vec![1.0] },
                            deadline_ms: None,
                            priority: None,
                        }
                        .to_line()
                        .as_bytes(),
                    )
                    .unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                match Response::parse(line.trim()).unwrap() {
                    Response::Score { score, .. } => assert!(score > 0.0),
                    other => panic!("{backend:?} round {round}: got {other:?}"),
                }
            }
            drop(idle); // close all at once
        }
        // Wait for the server to observe the closes, then verify it
        // still serves and shed nothing.
        let mut client = Client::connect(&addr).unwrap();
        client.ping().unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(stats.overloaded, 0, "backend {backend:?}");
        assert_eq!(stats.served, 20, "backend {backend:?}");
        assert_eq!(stats.accepted_conns as usize, 2 * pile + 1, "backend {backend:?}");
        server.shutdown();
    }
}

/// Half-close: the client pipelines requests then shuts down its write
/// half. Every pipelined request must still be answered before the
/// server closes the read side.
#[test]
fn half_close_still_answers_the_pipeline() {
    for backend in backends() {
        let server = binary_server(backend);
        let addr = server.local_addr().to_string();
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let n = 20;
        for i in 0..n {
            (&stream)
                .write_all(
                    Request::Score {
                        id: Some(i),
                        model: None,
                        features: Features::Sparse { idx: vec![3], val: vec![1.0] },
                        deadline_ms: None,
                        priority: None,
                    }
                    .to_line()
                    .as_bytes(),
                )
                .unwrap();
        }
        stream.shutdown(Shutdown::Write).unwrap();
        let mut answered = 0u64;
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line).unwrap_or(0) == 0 {
                break; // server finished and closed
            }
            match Response::parse(line.trim()).unwrap() {
                Response::Score { id, .. } => {
                    assert_eq!(id, Some(answered), "backend {backend:?}: in order");
                    answered += 1;
                }
                other => panic!("{backend:?}: got {other:?}"),
            }
        }
        assert_eq!(answered, n, "backend {backend:?}: every pipelined request answered");
        server.shutdown();
    }
}

/// EOF mid-message, both backends: a final *unterminated* v1 line is
/// still processed (the threads backend's `read_line` hands it over at
/// EOF; the event loop matches), and a binary frame truncated by the
/// close draws one `BAD_FRAME`.
#[test]
fn eof_mid_message_matches_across_backends() {
    for backend in backends() {
        // Unterminated final line: ping without the newline, then FIN.
        let server = binary_server(backend);
        let addr = server.local_addr().to_string();
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        (&stream).write_all(b"{\"op\":\"ping\"}").unwrap(); // no \n
        stream.shutdown(Shutdown::Write).unwrap();
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "backend {backend:?}");
        assert!(
            matches!(Response::parse(line.trim()).unwrap(), Response::Pong),
            "{backend:?}: final unterminated line must still be served"
        );
        drop(reader);
        drop(stream);

        // Truncated binary frame: prefix + partial body, then FIN.
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        (&stream).write_all(b"{\"op\":\"hello\",\"proto\":2}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let wire = Frame::ScoreSparse { gen: 0, idx: vec![5], val: vec![1.0] }.encode();
        (&stream).write_all(&wire[..wire.len() - 3]).unwrap();
        stream.shutdown(Shutdown::Write).unwrap();
        match Frame::read_from(&mut reader, 1 << 20).unwrap() {
            Frame::Error { code, retryable, .. } => {
                assert_eq!(code, ErrorCode::BadFrame, "backend {backend:?}");
                assert!(!retryable);
            }
            other => panic!("{backend:?}: expected BadFrame on truncation, got {other:?}"),
        }
        let mut probe = [0u8; 1];
        assert_eq!(reader.read(&mut probe).unwrap_or(1), 0, "{backend:?}: then EOF");
        server.shutdown();
    }
}

/// Verbose classify end to end on every wire form, both backends: the
/// per-voter rows arrive, decompose the total, and the lean form stays
/// lean.
#[test]
fn verbose_classify_breakdown_over_the_wire() {
    for backend in backends() {
        let server = server_on(
            backend,
            vec![
                ("default".into(), flat_snapshot(1.0).into()),
                ("digits".into(), flat_ensemble().into()),
            ],
        );
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        let payload = Features::Sparse { idx: vec![5, 100, 300], val: vec![1.0, 1.0, 1.0] };

        // v1 JSON: verbose flag → per-voter rows.
        match client.classify_verbose(Some("digits"), payload.clone()).unwrap() {
            Response::ClassifyVerbose { label, voters, features_evaluated, per_voter, .. } => {
                assert_eq!(label, 0, "backend {backend:?}");
                assert_eq!(voters, 3);
                assert_eq!(per_voter.len(), 3);
                assert_eq!((per_voter[0].pos, per_voter[0].neg), (0, 1));
                let sum: usize = per_voter.iter().map(|r| r.features as usize).sum();
                assert_eq!(sum, features_evaluated, "rows decompose the total");
                for row in &per_voter {
                    assert!(row.vote == row.pos || row.vote == row.neg);
                }
            }
            other => panic!("{backend:?}: expected verbose classify, got {other:?}"),
        }
        // The lean op is unchanged.
        assert!(matches!(
            client.classify(Some("digits"), payload.clone()).unwrap(),
            Response::Classify { .. }
        ));

        // Binary wire: CLASSIFY_SPARSE_VERBOSE → CLASS_VERBOSE.
        assert_eq!(client.negotiate().unwrap(), 7);
        match client
            .classify_sparse_verbose(1, vec![5, 100, 300], vec![1.0, 1.0, 1.0], 0)
            .unwrap()
        {
            Response::ClassifyVerbose { label, per_voter, features_evaluated, .. } => {
                assert_eq!(label, 0, "backend {backend:?}");
                assert_eq!(per_voter.len(), 3);
                let sum: usize = per_voter.iter().map(|r| r.features as usize).sum();
                assert_eq!(sum, features_evaluated);
            }
            other => panic!("{backend:?}: expected verbose classify frame, got {other:?}"),
        }
        // Lean binary classify still answers with the compact CLASS.
        assert!(matches!(
            client.classify_sparse(1, vec![5], vec![1.0], 0).unwrap(),
            Response::Classify { .. }
        ));
        server.shutdown();
    }
}

/// Open-loop loadgen against the event loop: hundreds of mostly-idle
/// connections over 2 I/O threads, zero sheds, zero errors. (The CI
/// bench-smoke job scales this same path to 2000 connections; the
/// thread backend is exempt by design — it would need 2×N threads.)
#[cfg(target_os = "linux")]
#[test]
fn open_loop_many_idle_connections_event_loop_zero_sheds() {
    use attentive::server::loadgen::{self, ClientMode, LoadGenConfig};
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        io_backend: IoBackend::EventLoop,
        event_threads: 2,
        workers: 2,
        queue: 1024,
        ..Default::default()
    };
    let server =
        TcpServer::serve_models(&cfg, vec![("default".into(), flat_snapshot(1.0).into())])
            .expect("bind");
    let addr = server.local_addr().to_string();
    let report = loadgen::run(&LoadGenConfig {
        addr,
        connections: 400,
        requests: 800,
        mode: ClientMode::V2Binary,
        hard_fraction: 0.2,
        open_loop: true,
        seed: 5,
        ..Default::default()
    })
    .expect("open-loop loadgen");
    assert_eq!(report.sent, 800);
    assert_eq!(report.answered, 800, "every open-loop request answered");
    assert_eq!(report.overloaded, 0, "zero sheds across mostly-idle connections");
    assert_eq!(report.errors, 0);
    let stats = server.shutdown();
    assert_eq!(stats.accepted_conns, 400);
    assert_eq!(stats.served, 800);
}
