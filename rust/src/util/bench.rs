//! Micro/macro benchmark harness (the offline build's criterion).
//!
//! `cargo bench` targets in `benches/` use `harness = false`, so each is
//! a plain binary; this module supplies the measurement discipline:
//! warmup, calibrated iteration counts, repeated samples, and robust
//! statistics (median + MAD), printed as aligned rows and optionally
//! written to CSV for EXPERIMENTS.md.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id (e.g. "fig3/attentive/train").
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Median absolute deviation (robust spread).
    pub mad_s: f64,
    /// Iterations per sample used.
    pub iters: u64,
    /// Samples taken.
    pub samples: usize,
    /// Optional throughput denominator (items per iteration); when set,
    /// reports items/s.
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    /// items/s if `items_per_iter` was provided.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|it| it / self.median_s)
    }

    /// Human row: `name  median  ±mad  [throughput]`.
    pub fn row(&self) -> String {
        let tput = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitems/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitems/s", t / 1e3),
            Some(t) => format!("  {t:8.2} items/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} ±{:>10}{}",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mad_s),
            tput
        )
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with fixed measurement discipline.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Target seconds of warmup.
    pub warmup_s: f64,
    /// Target seconds per sample.
    pub sample_s: f64,
    /// Number of samples.
    pub samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_s: 0.3, sample_s: 0.4, samples: 7, results: Vec::new() }
    }
}

impl Bench {
    /// Harness with default discipline (≈3 s per benchmark).
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick harness for CI / smoke runs.
    pub fn quick() -> Self {
        Self { warmup_s: 0.05, sample_s: 0.08, samples: 3, results: Vec::new() }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn measure(&mut self, name: impl Into<String>, mut f: impl FnMut()) -> &Measurement {
        self.measure_with_items(name, None, move || f())
    }

    /// Measure with a throughput denominator (items processed per call).
    pub fn measure_with_items(
        &mut self,
        name: impl Into<String>,
        items_per_iter: Option<f64>,
        mut f: impl FnMut(),
    ) -> &Measurement {
        // Warmup + calibration: find iters such that one sample ≈ sample_s.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed().as_secs_f64() < self.warmup_s {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.sample_s / per_iter).ceil() as u64).max(1);

        let mut times: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let s = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(s.elapsed().as_secs_f64() / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        let m = Measurement {
            name: name.into(),
            median_s: median,
            mad_s: mad,
            iters,
            samples: self.samples,
            items_per_iter,
        };
        println!("{}", m.row());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// All measurements so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write results as CSV (`name,median_s,mad_s,iters,throughput`).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,median_s,mad_s,iters,throughput_items_s")?;
        for m in &self.results {
            writeln!(
                f,
                "{},{},{},{},{}",
                m.name,
                m.median_s,
                m.mad_s,
                m.iters,
                m.throughput().map(|t| t.to_string()).unwrap_or_default()
            )?;
        }
        Ok(())
    }
}

/// Prevent the optimizer from discarding a value (stable black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench { warmup_s: 0.01, sample_s: 0.01, samples: 3, results: Vec::new() };
        let mut acc = 0u64;
        b.measure("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let m = &b.results()[0];
        assert!(m.median_s > 0.0);
        assert!(m.iters >= 1);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench { warmup_s: 0.01, sample_s: 0.01, samples: 3, results: Vec::new() };
        b.measure_with_items("t", Some(100.0), || {
            black_box(0u64);
        });
        assert!(b.results()[0].throughput().unwrap() > 0.0);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).contains("s"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).contains("ns"));
    }

    #[test]
    fn csv_export() {
        let mut b = Bench { warmup_s: 0.005, sample_s: 0.005, samples: 2, results: Vec::new() };
        b.measure("x", || {
            black_box(1 + 1);
        });
        let dir = crate::util::tempdir::TempDir::new("benchcsv");
        let p = dir.path().join("out.csv");
        b.write_csv(&p).unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert!(content.starts_with("name,median_s"));
        assert!(content.lines().count() == 2);
    }
}
