//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Replaces `rand`/`rand_chacha` in this offline build. Properties the
//! stack relies on:
//!
//! * **Reproducibility** — the same seed yields the same stream on every
//!   platform (pure integer arithmetic, no platform entropy).
//! * **Stream splitting** — `Rng64::split(tag)` derives an independent
//!   stream, used to key per-run / per-cell simulation RNGs.
//! * **Quality** — xoshiro256++ passes BigCrush; far more than the
//!   bounded-walk simulations and Fisher–Yates shuffles here require.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic generator.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    /// Seed via SplitMix64 expansion (any u64, including 0, is fine).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Self { s }
    }

    /// Derive an independent stream keyed by `tag` (order-free: derived
    /// streams don't perturb this one).
    pub fn split(&self, tag: u64) -> Rng64 {
        let mut sm = self.s[0] ^ self.s[2] ^ tag.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng64 { s }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n) (n > 0). Lemire-style rejection-free for
    /// our purposes: modulo bias is < 2⁻⁵³ for the n values used here,
    /// but we use widening multiply anyway for exactness.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // widening multiply: floor(x * n / 2^64) is uniform enough via
        // 128-bit arithmetic and exact for n << 2^64
        let x = self.next_u64() as u128;
        ((x * n as u128) >> 64) as usize
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// deterministic).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng64::seed_from_u64(42);
        let mut b = Rng64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_uniform_ish() {
        let mut r = Rng64::seed_from_u64(7);
        let n = 100_000;
        let mut sum = 0.0;
        let mut lo_count = 0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
            if v < 0.5 {
                lo_count += 1;
            }
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        let frac = lo_count as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "lo fraction {frac}");
    }

    #[test]
    fn below_is_uniform_over_small_range() {
        let mut r = Rng64::seed_from_u64(11);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!((c as i64 - expect as i64).abs() < (expect / 10) as i64, "counts {counts:?}");
        }
    }

    #[test]
    fn split_streams_are_independent_and_stable() {
        let base = Rng64::seed_from_u64(5);
        let mut a1 = base.split(1);
        let mut a2 = base.split(1);
        let mut b = base.split(2);
        assert_eq!(a1.next_u64(), a2.next_u64(), "same tag, same stream");
        assert_ne!(a1.next_u64(), b.next_u64(), "different tags differ");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::seed_from_u64(3);
        let n = 50_000;
        let vals: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = vals.iter().sum::<f64>() / n as f64;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng64::seed_from_u64(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "100 elements shuffled in place");
    }

    #[test]
    fn range_usize_inclusive_bounds() {
        let mut r = Rng64::seed_from_u64(1);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            let v = r.range_usize(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }
}
