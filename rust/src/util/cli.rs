//! Minimal declarative CLI argument parser (the offline build's clap).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! `--switch`, positional arguments, defaults, and auto-generated help.
//! The `attentive` binary's needs only — not a general framework.

use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Every occurrence of each flag, in order (`--model a --model b`
    /// keeps both; single-value accessors read the last).
    flags: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw tokens (everything after the subcommand). Flags named in
    /// `switches` are boolean and never consume a value.
    pub fn parse_with(tokens: &[String], switches: &[&str]) -> Result<Self, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(rest) = t.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if switches.contains(&rest) {
                    out.switches.push(rest.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.flags.entry(rest.to_string()).or_default().push(tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(rest.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Parse with no declared boolean switches.
    pub fn parse(tokens: &[String]) -> Result<Self, String> {
        Self::parse_with(tokens, &[])
    }

    /// String flag with default (last occurrence wins).
    pub fn get(&self, key: &str, default: &str) -> String {
        self.opt(key).map(str::to_string).unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag (last occurrence wins).
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable flag, in command-line order
    /// (empty when absent) — e.g. `serve --model a=1.json --model b=2.json`.
    pub fn opt_all(&self, key: &str) -> Vec<&str> {
        self.flags.get(key).map_or_else(Vec::new, |v| v.iter().map(|s| s.as_str()).collect())
    }

    /// Parsed numeric flag with default (last occurrence wins).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Boolean switch present?
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// Positional argument by index.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn flags_and_switches() {
        let a = Args::parse_with(
            &toks(&["--walks", "500", "--csv=out.csv", "--audit", "positional"]),
            &["audit"],
        )
        .unwrap();
        assert_eq!(a.get("walks", "0"), "500");
        assert_eq!(a.get("csv", ""), "out.csv");
        assert!(a.has("audit"));
        assert!(!a.has("missing"));
        assert_eq!(a.pos(0), Some("positional"));
    }

    #[test]
    fn numeric_parsing_with_default() {
        let a = Args::parse(&toks(&["--n", "42"])).unwrap();
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 42);
        assert_eq!(a.get_parse("m", 7usize).unwrap(), 7);
        let bad = Args::parse(&toks(&["--n", "xyz"])).unwrap();
        assert!(bad.get_parse("n", 0usize).is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse(&toks(&["--verbose"])).unwrap();
        assert!(a.has("verbose"));
    }

    #[test]
    fn repeated_flags_keep_every_occurrence() {
        let a = Args::parse(&toks(&[
            "--model",
            "a=one.json",
            "--model=b=two.json",
            "--workers",
            "2",
            "--workers",
            "4",
        ]))
        .unwrap();
        assert_eq!(a.opt_all("model"), vec!["a=one.json", "b=two.json"]);
        assert_eq!(a.opt("workers"), Some("4"), "single-value reads take the last");
        assert_eq!(a.get_parse("workers", 0usize).unwrap(), 4);
        assert!(a.opt_all("missing").is_empty());
    }
}
