//! Randomized property testing (the offline build's proptest).
//!
//! [`forall`] runs a property over `cases` random inputs drawn by a
//! user-supplied generator; on failure it re-runs a simple halving-style
//! shrink loop (via the generator's `size` hint) and panics with the
//! failing seed so the case is reproducible by construction.

use super::rng::Rng64;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (each case derives `seed ^ case_index`).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cases` inputs produced by `gen` at decreasing sizes
/// on failure. `gen(rng, size)` should scale input complexity with
/// `size ∈ (0, 1]`. Panics with the reproducing seed on failure.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    gen: impl Fn(&mut Rng64, f64) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng64::seed_from_u64(case_seed);
        let input = gen(&mut rng, 1.0);
        if let Err(msg) = prop(&input) {
            // Shrink: retry the same stream at smaller sizes and report the
            // smallest failing input found.
            let mut smallest: (f64, T, String) = (1.0, input, msg);
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                let mut rng = Rng64::seed_from_u64(case_seed);
                let candidate = gen(&mut rng, size);
                if let Err(m) = prop(&candidate) {
                    smallest = (size, candidate, m);
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, size {}):\n  input: {:?}\n  error: {}",
                smallest.0, smallest.1, smallest.2
            );
        }
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(
            Config { cases: 50, seed: 1 },
            |rng, size| {
                let n = 1 + (size * 20.0) as usize;
                (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect::<Vec<f64>>()
            },
            |xs| {
                if xs.iter().all(|x| x.abs() <= 1.0) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            Config { cases: 20, seed: 2 },
            |rng, _| rng.below(100),
            |&n| if n < 90 { Ok(()) } else { Err(format!("{n} >= 90")) },
        );
    }
}
