//! JSON value model, recursive-descent parser, and printer.
//!
//! Replaces `serde_json` in this offline build. Full RFC 8259 surface:
//! objects, arrays, strings with escapes (incl. `\uXXXX` + surrogate
//! pairs), numbers, booleans, null. Used for experiment configs, report
//! export, and model snapshots.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so printing is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64, like JavaScript).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset in the input.
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors / accessors -------------------------------------

    /// Object from pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// `self` as f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// `self` as u64 if a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    /// `self` as i64 if an integer.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    /// `self` as usize if a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    /// `self` as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `self` as &str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// `self` as array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // ---- printing ------------------------------------------------------

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }

    // ---- parsing ---------------------------------------------------------

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let b = input.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => {
                            out.push('"');
                            self.i += 1;
                        }
                        Some(b'\\') => {
                            out.push('\\');
                            self.i += 1;
                        }
                        Some(b'/') => {
                            out.push('/');
                            self.i += 1;
                        }
                        Some(b'n') => {
                            out.push('\n');
                            self.i += 1;
                        }
                        Some(b't') => {
                            out.push('\t');
                            self.i += 1;
                        }
                        Some(b'r') => {
                            out.push('\r');
                            self.i += 1;
                        }
                        Some(b'b') => {
                            out.push('\u{0008}');
                            self.i += 1;
                        }
                        Some(b'f') => {
                            out.push('\u{000C}');
                            self.i += 1;
                        }
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let cp = 0x10000
                                        + (((hi - 0xD800) as u32) << 10)
                                        + (lo - 0xDC00) as u32;
                                    char::from_u32(cp).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(hi as u32)
                                    .ok_or_else(|| self.err("bad \\u escape"))?
                            };
                            out.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u16::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_round_trip() {
        let original = Json::Str("line\n\"quoted\"\tßnow☃".into());
        let printed = original.to_string_compact();
        let back = Json::parse(&printed).unwrap();
        assert_eq!(back, original);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert!(Json::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn pretty_round_trip() {
        let v = Json::obj([
            ("name", Json::Str("fig3".into())),
            ("runs", Json::Num(10.0)),
            ("deltas", Json::Arr(vec![Json::Num(0.1), Json::Num(0.2)])),
            ("nested", Json::obj([("ok", Json::Bool(true))])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains("\n"));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.at >= 6, "error at {}", e.at);
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("[1] trailing").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integer_printing_is_clean() {
        assert_eq!(Json::Num(10.0).to_string_compact(), "10");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
        assert_eq!(Json::Num(-3.0).to_string_compact(), "-3");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "f": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("neg").unwrap().as_i64(), Some(-2));
        assert_eq!(v.get("neg").unwrap().as_u64(), None);
        assert_eq!(v.get("missing"), None);
    }
}
