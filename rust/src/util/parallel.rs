//! Scoped-thread parallel map (the offline build's rayon).
//!
//! [`par_map`] fans a work list out over `min(jobs, cpus)` scoped worker
//! threads pulling indices from a shared atomic counter (work stealing by
//! construction), and returns results **in input order** — determinism is
//! guaranteed as long as each job is itself deterministic in its inputs,
//! regardless of thread scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use for `n` jobs.
pub fn default_workers(n: usize) -> usize {
    let cpus = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    cpus.min(n).max(1)
}

/// Apply `f` to each item in parallel, preserving input order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = default_workers(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    // SAFETY-free approach: split `slots` into one &mut cell per index via
    // chunk iteration is awkward with dynamic claiming, so collect results
    // per worker with indices and scatter afterwards.
    let results: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut mine = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        mine.push((i, f(&items[i])));
                    }
                    mine
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("par_map worker panicked")).collect()
    });
    for bucket in results {
        for (i, r) in bucket {
            slots[i] = Some(r);
        }
    }
    slots.into_iter().map(|s| s.expect("par_map slot unfilled")).collect()
}

/// Parallel for-each over index range `0..n` (no results collected).
pub fn par_for(n: usize, f: impl Fn(usize) + Sync) {
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, |&i| f(i));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |&i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let count = AtomicU64::new(0);
        let items: Vec<usize> = (0..257).collect();
        par_map(&items, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 257);
    }

    #[test]
    fn empty_and_singleton() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |&x| x).is_empty());
        assert_eq!(par_map(&[7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn actually_uses_threads_for_cpu_work() {
        // Smoke test: heavy jobs complete and produce correct values.
        let items: Vec<u64> = (0..32).collect();
        let out = par_map(&items, |&i| {
            let mut acc = i;
            for _ in 0..100_000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            acc
        });
        // deterministic regardless of scheduling
        let seq: Vec<u64> = items
            .iter()
            .map(|&i| {
                let mut acc = i;
                for _ in 0..100_000 {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
                }
                acc
            })
            .collect();
        assert_eq!(out, seq);
    }

    #[test]
    fn par_for_covers_range() {
        let hits = AtomicU64::new(0);
        par_for(100, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }
}
