//! RAII temporary directories (the offline build's `tempfile`).
//!
//! Creates a uniquely named directory under the system temp dir and
//! removes it (recursively) on drop. Uniqueness comes from a process-wide
//! atomic counter + PID + a time component, so parallel tests never
//! collide.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `$TMPDIR/attentive-<prefix>-<pid>-<n>-<t>`.
    pub fn new(prefix: &str) -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!(
            "attentive-{prefix}-{}-{n}-{t}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        Self { path }
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new("t");
            p = d.path().to_path_buf();
            assert!(p.exists());
            std::fs::write(p.join("f.txt"), "x").unwrap();
        }
        assert!(!p.exists(), "dir should be removed on drop");
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u");
        let b = TempDir::new("u");
        assert_ne!(a.path(), b.path());
    }
}
