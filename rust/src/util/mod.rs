//! In-tree substrates for an offline build.
//!
//! The build environment vendors only `xla`, `anyhow`, and `thiserror`;
//! every other facility the stack needs is implemented here from scratch:
//!
//! * [`rng`] — deterministic xoshiro256++ PRNG (replaces `rand`/
//!   `rand_chacha`): seedable, splittable streams, uniform ranges.
//! * [`json`] — a complete JSON value model, parser, and printer
//!   (replaces `serde_json` for configs, reports, and exports).
//! * [`parallel`] — scoped-thread parallel map with deterministic output
//!   order (replaces `rayon` for the sweep scheduler and simulators).
//! * [`bench`] — a micro/macro-benchmark harness with warmup, repeats,
//!   and robust statistics (replaces `criterion` for `cargo bench`).
//! * [`cli`] — a tiny declarative argument parser (replaces `clap`).
//! * [`check`] — randomized property-testing loops with shrinking-lite
//!   counterexample reporting (replaces `proptest`).
//! * [`tempdir`] — RAII temporary directories for tests.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod tempdir;
