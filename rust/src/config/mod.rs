//! Experiment configuration.
//!
//! A single [`ExperimentConfig`] JSON document describes a full run:
//! dataset source, task pair, learner/boundary family, coordinate policy,
//! stream length and seeds. The CLI (`attentive train --config exp.json`)
//! and the bench harness both consume it, so every figure is reproducible
//! from a checked-in config.

use std::path::{Path, PathBuf};


use crate::error::{Error, Result};
use crate::margin::policy::CoordinatePolicy;
use crate::stst::boundary::AnyBoundary;
use crate::util::json::Json;

/// Where training data comes from.
#[derive(Debug, Clone)]
pub enum DataConfig {
    /// Deterministic synthetic digit glyphs (the MNIST stand-in).
    Synth {
        /// RNG seed for the generator.
        seed: u64,
        /// Number of examples to generate (split into train/test).
        count: usize,
    },
    /// Real MNIST IDX files in a directory (falls back to synth+warn if
    /// absent when `fallback_synth` is set).
    Mnist {
        /// Directory holding `train-images-idx3-ubyte` etc.
        dir: PathBuf,
        /// Fall back to the synthetic generator when files are missing.
        fallback_synth: bool,
    },
    /// A libsvm text file with ±1 labels.
    Libsvm {
        /// Path to the file.
        path: PathBuf,
        /// Dense feature dimensionality.
        dim: usize,
    },
}

/// Which learner family to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearnerKind {
    /// Pegasos under the configured boundary (the paper's trio:
    /// boundary=full → Pegasos, constant → Attentive, budgeted → Budgeted).
    Pegasos,
    /// Perceptron under the configured boundary (extension).
    Perceptron,
    /// Passive-Aggressive I under the configured boundary (extension).
    PassiveAggressive,
}

/// Everything needed to reproduce one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Experiment name (used for output file naming).
    pub name: String,
    /// Data source.
    pub data: DataConfig,
    /// 1-vs-1 pair: positive, negative original class labels.
    pub pair: (i64, i64),
    /// Train fraction of the data (rest is test).
    pub train_fraction: f64,
    /// Learner family.
    pub learner: LearnerKind,
    /// Stopping boundary.
    pub boundary: AnyBoundary,
    /// Coordinate selection policy.
    pub policy: CoordinatePolicy,
    /// Pegasos regularization λ.
    pub lambda: f64,
    /// Margin decision threshold θ (1.0 = hinge).
    pub theta: f64,
    /// Number of passes over the training set.
    pub epochs: u64,
    /// Runs to average (paper: 10 permutations).
    pub runs: u64,
    /// Base RNG seed.
    pub seed: u64,
    /// Evaluate test error every this many examples.
    pub eval_every: u64,
    /// Finish stopped evaluations out-of-band to audit decision errors.
    pub audit: bool,
}

fn default_train_fraction() -> f64 {
    0.8
}
fn default_lambda() -> f64 {
    1e-4
}
fn default_theta() -> f64 {
    1.0
}
fn default_epochs() -> u64 {
    1
}
fn default_runs() -> u64 {
    10
}
fn default_eval_every() -> u64 {
    200
}

impl DataConfig {
    /// Serialize as a tagged JSON object.
    pub fn to_json(&self) -> Json {
        match self {
            DataConfig::Synth { seed, count } => Json::obj([
                ("source", Json::Str("synth".into())),
                ("seed", Json::Num(*seed as f64)),
                ("count", Json::Num(*count as f64)),
            ]),
            DataConfig::Mnist { dir, fallback_synth } => Json::obj([
                ("source", Json::Str("mnist".into())),
                ("dir", Json::Str(dir.display().to_string())),
                ("fallback_synth", Json::Bool(*fallback_synth)),
            ]),
            DataConfig::Libsvm { path, dim } => Json::obj([
                ("source", Json::Str("libsvm".into())),
                ("path", Json::Str(path.display().to_string())),
                ("dim", Json::Num(*dim as f64)),
            ]),
        }
    }

    /// Parse the tagged JSON form.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let source = v.get("source").and_then(|s| s.as_str()).ok_or("data: missing source")?;
        match source {
            "synth" => Ok(DataConfig::Synth {
                seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(0),
                count: v.get("count").and_then(|x| x.as_usize()).ok_or("synth: missing count")?,
            }),
            "mnist" => Ok(DataConfig::Mnist {
                dir: PathBuf::from(
                    v.get("dir").and_then(|x| x.as_str()).ok_or("mnist: missing dir")?,
                ),
                fallback_synth: v.get("fallback_synth").and_then(|x| x.as_bool()).unwrap_or(false),
            }),
            "libsvm" => Ok(DataConfig::Libsvm {
                path: PathBuf::from(
                    v.get("path").and_then(|x| x.as_str()).ok_or("libsvm: missing path")?,
                ),
                dim: v.get("dim").and_then(|x| x.as_usize()).ok_or("libsvm: missing dim")?,
            }),
            other => Err(format!("unknown data source {other:?}")),
        }
    }
}

impl LearnerKind {
    /// Kebab-case name.
    pub fn name(self) -> &'static str {
        match self {
            LearnerKind::Pegasos => "pegasos",
            LearnerKind::Perceptron => "perceptron",
            LearnerKind::PassiveAggressive => "passive-aggressive",
        }
    }

    /// Parse the kebab-case name.
    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "pegasos" => Ok(LearnerKind::Pegasos),
            "perceptron" => Ok(LearnerKind::Perceptron),
            "passive-aggressive" => Ok(LearnerKind::PassiveAggressive),
            other => Err(format!("unknown learner {other:?}")),
        }
    }
}

impl ExperimentConfig {
    /// Paper defaults: synthetic digits, 2-vs-3, Attentive Pegasos with
    /// the Constant STST at δ = 0.1, weight-sampled coordinates.
    pub fn paper_default() -> Self {
        Self {
            name: "fig3-2v3-attentive".into(),
            data: DataConfig::Synth { seed: 7, count: 4_000 },
            pair: (2, 3),
            train_fraction: default_train_fraction(),
            learner: LearnerKind::Pegasos,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::WeightSampled,
            lambda: default_lambda(),
            theta: default_theta(),
            epochs: 5,
            runs: default_runs(),
            seed: 0,
            eval_every: default_eval_every(),
            audit: false,
        }
    }

    /// Serialize as JSON.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::Str(self.name.clone())),
            ("data", self.data.to_json()),
            ("pair", Json::Arr(vec![Json::Num(self.pair.0 as f64), Json::Num(self.pair.1 as f64)])),
            ("train_fraction", Json::Num(self.train_fraction)),
            ("learner", Json::Str(self.learner.name().into())),
            ("boundary", self.boundary.to_json()),
            ("policy", Json::Str(self.policy.name().into())),
            ("lambda", Json::Num(self.lambda)),
            ("theta", Json::Num(self.theta)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("runs", Json::Num(self.runs as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("audit", Json::Bool(self.audit)),
        ])
    }

    /// Parse from JSON (missing optional fields take paper defaults).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let pair = v.get("pair").and_then(|p| p.as_arr()).ok_or("config: missing pair")?;
        if pair.len() != 2 {
            return Err("config: pair must have 2 entries".into());
        }
        Ok(Self {
            name: v.get("name").and_then(|s| s.as_str()).ok_or("config: missing name")?.into(),
            data: DataConfig::from_json(v.get("data").ok_or("config: missing data")?)?,
            pair: (
                pair[0].as_i64().ok_or("pair[0] not an int")?,
                pair[1].as_i64().ok_or("pair[1] not an int")?,
            ),
            train_fraction: v
                .get("train_fraction")
                .and_then(|x| x.as_f64())
                .unwrap_or_else(default_train_fraction),
            learner: LearnerKind::from_name(
                v.get("learner").and_then(|s| s.as_str()).ok_or("config: missing learner")?,
            )?,
            boundary: AnyBoundary::from_json(v.get("boundary").ok_or("config: missing boundary")?)?,
            policy: CoordinatePolicy::from_name(
                v.get("policy").and_then(|s| s.as_str()).ok_or("config: missing policy")?,
            )?,
            lambda: v.get("lambda").and_then(|x| x.as_f64()).unwrap_or_else(default_lambda),
            theta: v.get("theta").and_then(|x| x.as_f64()).unwrap_or_else(default_theta),
            epochs: v.get("epochs").and_then(|x| x.as_u64()).unwrap_or_else(default_epochs),
            runs: v.get("runs").and_then(|x| x.as_u64()).unwrap_or_else(default_runs),
            seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(0),
            eval_every: v
                .get("eval_every")
                .and_then(|x| x.as_u64())
                .unwrap_or_else(default_eval_every),
            audit: v.get("audit").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }

    /// Load from a JSON file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        let doc = Json::parse(&text)
            .map_err(|e| Error::format(format!("config {}", path.display()), e.to_string()))?;
        let cfg = Self::from_json(&doc)
            .map_err(|e| Error::format(format!("config {}", path.display()), e))?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Save to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty()).map_err(|e| Error::io(path, e))
    }

    /// Sanity-check field ranges.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.train_fraction) {
            return Err(Error::Config(format!("train_fraction {} not in [0,1]", self.train_fraction)));
        }
        if self.lambda <= 0.0 {
            return Err(Error::Config(format!("lambda {} must be > 0", self.lambda)));
        }
        if self.pair.0 == self.pair.1 {
            return Err(Error::Config(format!("pair classes identical: {:?}", self.pair)));
        }
        if let AnyBoundary::Constant { delta, .. } | AnyBoundary::Curved { delta } = self.boundary {
            if !(0.0 < delta && delta < 1.0) {
                return Err(Error::Config(format!("delta {delta} not in (0,1)")));
            }
        }
        if self.runs == 0 || self.epochs == 0 {
            return Err(Error::Config("runs and epochs must be >= 1".into()));
        }
        Ok(())
    }
}

/// Which transport backend the TCP front-end runs requests through.
///
/// `EventLoop` is the epoll-based nonblocking backend
/// (`rust/src/server/event_loop.rs`, Linux only, and the default
/// there): `event_threads` sharded loops multiplex every connection,
/// scaling to thousands of mostly-idle sockets with an
/// allocation-free steady-state hot path. `Threads` is the original
/// reader/writer thread pair per connection — simple and portable, it
/// remains the default (and only) backend off Linux and the explicit
/// fallback everywhere (`--io-backend threads`). Both speak the
/// identical wire protocol; see `docs/PERFORMANCE.md` for the
/// measured trade-offs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoBackend {
    /// Reader/writer thread pair per connection (portable fallback;
    /// default off Linux).
    Threads,
    /// Sharded epoll event loops (Linux only; default there).
    EventLoop,
}

impl Default for IoBackend {
    /// Platform default: the event loop wherever epoll exists (Linux),
    /// the portable thread backend everywhere else.
    fn default() -> Self {
        if cfg!(target_os = "linux") {
            IoBackend::EventLoop
        } else {
            IoBackend::Threads
        }
    }
}

impl IoBackend {
    /// Kebab-case wire/config name.
    pub fn name(self) -> &'static str {
        match self {
            IoBackend::Threads => "threads",
            IoBackend::EventLoop => "event-loop",
        }
    }

    /// Parse the config name (both `event-loop` and `event_loop` are
    /// accepted — the latter is what shells pass most naturally).
    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "threads" => Ok(IoBackend::Threads),
            "event-loop" | "event_loop" => Ok(IoBackend::EventLoop),
            other => Err(format!("unknown io backend {other:?} (threads | event-loop)")),
        }
    }

    /// The default backend, overridable with `ATTENTIVE_IO_BACKEND`.
    /// The env hook exists so the serving integration tests run
    /// unmodified against either backend (CI exercises both); unset
    /// means the platform default ([`IoBackend::default`]: event loop
    /// on Linux, threads elsewhere).
    ///
    /// # Panics
    ///
    /// On an unparseable value. The variable's whole purpose is to
    /// redirect a test run onto a specific backend — a typo silently
    /// falling back to `Threads` would turn that run into a vacuous
    /// duplicate (and un-gate the event loop in CI), so it fails loudly
    /// instead.
    pub fn default_from_env() -> Self {
        match std::env::var("ATTENTIVE_IO_BACKEND") {
            Ok(s) => IoBackend::from_name(s.trim())
                .unwrap_or_else(|e| panic!("ATTENTIVE_IO_BACKEND: {e}")),
            Err(_) => IoBackend::default(),
        }
    }
}

/// Online trainer attached behind the wire (the `learn` op): one
/// background trainer thread per registry shard owning a live attentive
/// learner, consuming labeled examples from a bounded queue and
/// periodically publishing immutable snapshots into the shard's
/// [`crate::server::hub::ModelHub`] generation swap. See
/// [`crate::coordinator::online`].
#[derive(Debug, Clone, PartialEq)]
pub struct TrainerWireConfig {
    /// Per-shard learn-queue depth; examples beyond it are shed with an
    /// explicit retryable `overloaded` ack instead of buffering.
    pub queue: usize,
    /// Publish a fresh snapshot after this many model *updates*
    /// (0 = never publish by count).
    pub publish_every_updates: u64,
    /// ... and/or after this many milliseconds since the last publish,
    /// whichever fires first (0 = never publish by time). At least one
    /// cadence must be nonzero.
    pub publish_every_ms: u64,
    /// Learner family. The wire trainer currently supports `pegasos`
    /// only (snapshot publishing needs its variance cache).
    pub learner: LearnerKind,
    /// Pegasos regularization λ.
    pub lambda: f64,
    /// Training-time stopping boundary (the attentive early exit).
    pub boundary: AnyBoundary,
    /// Coordinate selection policy.
    pub policy: CoordinatePolicy,
    /// Trainer RNG seed.
    pub seed: u64,
}

impl Default for TrainerWireConfig {
    fn default() -> Self {
        Self {
            queue: 1024,
            publish_every_updates: 64,
            publish_every_ms: 250,
            learner: LearnerKind::Pegasos,
            lambda: 1e-2,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::WeightSampled,
            seed: 0,
        }
    }
}

impl TrainerWireConfig {
    /// Serialize as JSON.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("queue", Json::Num(self.queue as f64)),
            ("publish_every_updates", Json::Num(self.publish_every_updates as f64)),
            ("publish_every_ms", Json::Num(self.publish_every_ms as f64)),
            ("learner", Json::Str(self.learner.name().into())),
            ("lambda", Json::Num(self.lambda)),
            ("boundary", self.boundary.to_json()),
            ("policy", Json::Str(self.policy.name().into())),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    /// Parse from JSON; missing fields take the defaults.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let d = TrainerWireConfig::default();
        Ok(Self {
            queue: v.get("queue").and_then(|x| x.as_usize()).unwrap_or(d.queue),
            publish_every_updates: v
                .get("publish_every_updates")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.publish_every_updates),
            publish_every_ms: v
                .get("publish_every_ms")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.publish_every_ms),
            learner: match v.get("learner").and_then(|s| s.as_str()) {
                Some(name) => LearnerKind::from_name(name)?,
                None => d.learner,
            },
            lambda: v.get("lambda").and_then(|x| x.as_f64()).unwrap_or(d.lambda),
            boundary: match v.get("boundary") {
                Some(b) => AnyBoundary::from_json(b)?,
                None => d.boundary,
            },
            policy: match v.get("policy").and_then(|s| s.as_str()) {
                Some(name) => CoordinatePolicy::from_name(name)?,
                None => d.policy,
            },
            seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(d.seed),
        })
    }

    /// Sanity-check field ranges.
    pub fn validate(&self) -> Result<()> {
        if self.queue == 0 {
            return Err(Error::Config("trainer queue must be >= 1".into()));
        }
        if self.publish_every_updates == 0 && self.publish_every_ms == 0 {
            return Err(Error::Config(
                "trainer needs a publish cadence: publish_every_updates and/or publish_every_ms"
                    .into(),
            ));
        }
        if self.lambda <= 0.0 {
            return Err(Error::Config(format!("trainer lambda {} must be > 0", self.lambda)));
        }
        if let AnyBoundary::Constant { delta, .. } | AnyBoundary::Curved { delta } = self.boundary {
            if !(0.0 < delta && delta < 1.0) {
                return Err(Error::Config(format!("trainer delta {delta} not in (0,1)")));
            }
        }
        if self.learner != LearnerKind::Pegasos {
            return Err(Error::Config(format!(
                "online trainer supports learner \"pegasos\" (got {:?})",
                self.learner.name()
            )));
        }
        Ok(())
    }
}

/// Overload-brownout controller configuration: a feedback loop per
/// serving shard that samples admission-queue occupancy (and, when
/// `latency_target_us` is set, a queue-wait EWMA) and moves the shard
/// through pressure tiers `normal → brown-1 → brown-2 → shed`. Brown
/// tiers swap in pre-scaled stopping-boundary tables — τ tightened by
/// `tighten` per tier — so scoring evaluates fewer features per example
/// exactly when the queue is deep; the `shed` tier additionally rejects
/// bulk-lane admissions. `None` on [`ServerConfig::brownout`] disables
/// the controller entirely and keeps scoring bit-identical to the
/// undegraded path. See `docs/OPERATIONS.md` ("Brownout tiers").
#[derive(Debug, Clone, PartialEq)]
pub struct BrownoutConfig {
    /// Multiplicative τ tightening per brown tier: tier 1 scales the
    /// boundary by `tighten`, tier 2 by `tighten²`. Must be in (0, 1].
    pub tighten: f64,
    /// Pressure (queue occupancy in [0,1], or wait-EWMA / target when a
    /// latency target is set — whichever is higher) above which the
    /// controller moves one tier up, after `dwell_ms` of persistence.
    pub enter: f64,
    /// Pressure below which the controller moves one tier down, after
    /// `dwell_ms` — strictly less than `enter` (the hysteresis band).
    pub exit: f64,
    /// Minimum milliseconds a tier-change condition must persist before
    /// the transition fires (flap damping).
    pub dwell_ms: u64,
    /// Controller sampling period in milliseconds.
    pub sample_ms: u64,
    /// Queue-wait EWMA target in microseconds; 0 (the default) makes
    /// the controller occupancy-only.
    pub latency_target_us: u64,
}

impl Default for BrownoutConfig {
    fn default() -> Self {
        Self {
            tighten: 0.5,
            enter: 0.75,
            exit: 0.35,
            dwell_ms: 200,
            sample_ms: 20,
            latency_target_us: 0,
        }
    }
}

impl BrownoutConfig {
    /// Serialize as JSON.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("tighten", Json::Num(self.tighten)),
            ("enter", Json::Num(self.enter)),
            ("exit", Json::Num(self.exit)),
            ("dwell_ms", Json::Num(self.dwell_ms as f64)),
            ("sample_ms", Json::Num(self.sample_ms as f64)),
            ("latency_target_us", Json::Num(self.latency_target_us as f64)),
        ])
    }

    /// Parse from JSON; missing fields take the defaults.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let d = BrownoutConfig::default();
        Ok(Self {
            tighten: v.get("tighten").and_then(|x| x.as_f64()).unwrap_or(d.tighten),
            enter: v.get("enter").and_then(|x| x.as_f64()).unwrap_or(d.enter),
            exit: v.get("exit").and_then(|x| x.as_f64()).unwrap_or(d.exit),
            dwell_ms: v.get("dwell_ms").and_then(|x| x.as_u64()).unwrap_or(d.dwell_ms),
            sample_ms: v.get("sample_ms").and_then(|x| x.as_u64()).unwrap_or(d.sample_ms),
            latency_target_us: v
                .get("latency_target_us")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.latency_target_us),
        })
    }

    /// Sanity-check field ranges.
    pub fn validate(&self) -> Result<()> {
        if !(self.tighten > 0.0 && self.tighten <= 1.0) {
            return Err(Error::Config(format!(
                "brownout tighten {} not in (0,1]",
                self.tighten
            )));
        }
        for (name, v) in [("enter", self.enter), ("exit", self.exit)] {
            if !(v > 0.0 && v <= 1.0) {
                return Err(Error::Config(format!("brownout {name} {v} not in (0,1]")));
            }
        }
        if self.enter <= self.exit {
            return Err(Error::Config(format!(
                "brownout enter {} must exceed exit {} (hysteresis band)",
                self.enter, self.exit
            )));
        }
        if self.sample_ms == 0 {
            return Err(Error::Config("brownout sample_ms must be >= 1".into()));
        }
        Ok(())
    }
}

/// Network serving front-end configuration (`attentive serve --listen` /
/// [`crate::server`]). A standalone JSON document, separate from
/// [`ExperimentConfig`]: serving deploys a finished model, it does not
/// describe a training run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Address to bind, e.g. `"127.0.0.1:7878"` (port 0 = ephemeral).
    pub listen: String,
    /// Prediction worker threads.
    pub workers: usize,
    /// Max requests drained per worker batch.
    pub max_batch: usize,
    /// Admission queue bound: requests beyond this are shed with an
    /// explicit `overloaded` response instead of buffering unboundedly.
    pub queue: usize,
    /// Max responses in flight per connection before the reader blocks
    /// (per-connection pipelining bound).
    pub max_pending_per_conn: usize,
    /// Protocol v2: cap on one binary frame's body length in bytes. A
    /// corrupt or hostile length prefix beyond this kills the
    /// connection instead of allocating.
    pub max_frame_bytes: usize,
    /// Protocol v2+: cap on nonzeros per sparse score/classify request.
    /// The legacy v2 `SCORE_SPARSE` frame is bounded at 65535 by its
    /// `nnz:u16` field regardless; the v3 frames carry `nnz:u32`, so
    /// this knob may range up to `u32::MAX` (the frame-byte cap is the
    /// practical bound).
    pub max_nnz: usize,
    /// Protocol v6: cap on examples per `SCORE_BATCH` / `score-batch`
    /// request. A batch beyond it is one whole-batch error (never a
    /// truncation); each admitted example is still screened against
    /// `max_nnz` individually. The default keeps a worst-case batch of
    /// `max_nnz`-wide examples far under `max_frame_bytes`.
    pub max_batch_examples: usize,
    /// Base RNG seed for the prediction-time coordinate policies.
    pub seed: u64,
    /// Transport backend: per-connection thread pairs (default) or the
    /// sharded epoll event loop. Overridable via `ATTENTIVE_IO_BACKEND`
    /// (see [`IoBackend::default_from_env`]).
    pub io_backend: IoBackend,
    /// Event-loop shards (I/O threads) for the `event-loop` backend;
    /// connections are assigned round-robin at accept. Ignored by the
    /// `threads` backend.
    pub event_threads: usize,
    /// Concurrent-connection admission cap: connections beyond it are
    /// accepted and immediately closed (so the kernel backlog never
    /// silently fills). Both backends enforce it; the event loop is the
    /// one that can realistically reach it.
    pub max_conns: usize,
    /// Per-connection write deadline in milliseconds, applied by both
    /// backends when flushing responses to a peer that has stopped
    /// reading (0 = wait forever). Bounds how long a dead or stalled
    /// peer can pin a writer.
    pub write_timeout_ms: u64,
    /// Per-connection idle deadline in milliseconds: a connection that
    /// has neither sent a byte nor has responses owed for this long is
    /// reaped (0 = never, the default). Both backends enforce it; it is
    /// the slowloris defense — idle peers stop pinning buffers forever.
    pub idle_timeout_ms: u64,
    /// Crash-safe online learning: when set, every trainer-backed shard
    /// persists its published snapshot generations under
    /// `<snapshot_dir>/<shard-name>/` (atomic temp+fsync+rename writes)
    /// and a restarting server warm-starts each trainer from the newest
    /// valid file there. `None` (the default) keeps learned state
    /// in-memory only.
    pub snapshot_dir: Option<PathBuf>,
    /// Attach an online trainer to every shard (enables the `learn` op).
    /// `None` (the default) serves inference-only.
    pub trainer: Option<TrainerWireConfig>,
    /// Overload-brownout controller (attention-tiered graceful
    /// degradation). `None` (the default) disables it: no controller
    /// thread, tier pinned at `normal`, scoring bit-identical to the
    /// undegraded path.
    pub brownout: Option<BrownoutConfig>,
    /// Default request deadline in milliseconds applied at admission to
    /// requests that carry none of their own (protocol v7
    /// `deadline_ms`); 0 (the default) means no default deadline — and
    /// with no per-request deadlines either, the deadline path costs
    /// nothing.
    pub deadline_default_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7878".into(),
            workers: 2,
            max_batch: 16,
            queue: 1024,
            max_pending_per_conn: 64,
            max_frame_bytes: 1 << 20,
            max_nnz: u16::MAX as usize,
            max_batch_examples: 128,
            seed: 0,
            io_backend: IoBackend::default_from_env(),
            event_threads: 2,
            max_conns: 16_384,
            write_timeout_ms: 2_000,
            idle_timeout_ms: 0,
            snapshot_dir: None,
            trainer: None,
            brownout: None,
            deadline_default_ms: 0,
        }
    }
}

impl ServerConfig {
    /// Serialize as JSON.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("listen", Json::Str(self.listen.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("queue", Json::Num(self.queue as f64)),
            ("max_pending_per_conn", Json::Num(self.max_pending_per_conn as f64)),
            ("max_frame_bytes", Json::Num(self.max_frame_bytes as f64)),
            ("max_nnz", Json::Num(self.max_nnz as f64)),
            ("max_batch_examples", Json::Num(self.max_batch_examples as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("io_backend", Json::Str(self.io_backend.name().into())),
            ("event_threads", Json::Num(self.event_threads as f64)),
            ("max_conns", Json::Num(self.max_conns as f64)),
            ("write_timeout_ms", Json::Num(self.write_timeout_ms as f64)),
            ("idle_timeout_ms", Json::Num(self.idle_timeout_ms as f64)),
            ("deadline_default_ms", Json::Num(self.deadline_default_ms as f64)),
        ];
        if let Some(dir) = &self.snapshot_dir {
            fields.push(("snapshot_dir", Json::Str(dir.display().to_string())));
        }
        if let Some(t) = &self.trainer {
            fields.push(("trainer", t.to_json()));
        }
        if let Some(b) = &self.brownout {
            fields.push(("brownout", b.to_json()));
        }
        Json::obj(fields)
    }

    /// Parse from JSON; missing fields take the defaults.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let d = ServerConfig::default();
        Ok(Self {
            listen: v.get("listen").and_then(|s| s.as_str()).unwrap_or(&d.listen).to_string(),
            workers: v.get("workers").and_then(|x| x.as_usize()).unwrap_or(d.workers),
            max_batch: v.get("max_batch").and_then(|x| x.as_usize()).unwrap_or(d.max_batch),
            queue: v.get("queue").and_then(|x| x.as_usize()).unwrap_or(d.queue),
            max_pending_per_conn: v
                .get("max_pending_per_conn")
                .and_then(|x| x.as_usize())
                .unwrap_or(d.max_pending_per_conn),
            max_frame_bytes: v
                .get("max_frame_bytes")
                .and_then(|x| x.as_usize())
                .unwrap_or(d.max_frame_bytes),
            max_nnz: v.get("max_nnz").and_then(|x| x.as_usize()).unwrap_or(d.max_nnz),
            max_batch_examples: v
                .get("max_batch_examples")
                .and_then(|x| x.as_usize())
                .unwrap_or(d.max_batch_examples),
            seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(d.seed),
            io_backend: match v.get("io_backend").and_then(|s| s.as_str()) {
                Some(name) => IoBackend::from_name(name)?,
                None => d.io_backend,
            },
            event_threads: v
                .get("event_threads")
                .and_then(|x| x.as_usize())
                .unwrap_or(d.event_threads),
            max_conns: v.get("max_conns").and_then(|x| x.as_usize()).unwrap_or(d.max_conns),
            write_timeout_ms: v
                .get("write_timeout_ms")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.write_timeout_ms),
            idle_timeout_ms: v
                .get("idle_timeout_ms")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.idle_timeout_ms),
            snapshot_dir: v
                .get("snapshot_dir")
                .and_then(|s| s.as_str())
                .map(PathBuf::from)
                .or(d.snapshot_dir),
            trainer: match v.get("trainer") {
                Some(t) => Some(TrainerWireConfig::from_json(t)?),
                None => d.trainer,
            },
            brownout: match v.get("brownout") {
                Some(b) => Some(BrownoutConfig::from_json(b)?),
                None => d.brownout,
            },
            deadline_default_ms: v
                .get("deadline_default_ms")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.deadline_default_ms),
        })
    }

    /// Load from a JSON file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        let doc = Json::parse(&text)
            .map_err(|e| Error::format(format!("server config {}", path.display()), e.to_string()))?;
        let cfg = Self::from_json(&doc)
            .map_err(|e| Error::format(format!("server config {}", path.display()), e))?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Save to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty()).map_err(|e| Error::io(path, e))
    }

    /// Sanity-check field ranges.
    pub fn validate(&self) -> Result<()> {
        if self.listen.is_empty() {
            return Err(Error::Config("server listen address must not be empty".into()));
        }
        for (name, v) in [
            ("workers", self.workers),
            ("max_batch", self.max_batch),
            ("queue", self.queue),
            ("max_pending_per_conn", self.max_pending_per_conn),
            ("max_frame_bytes", self.max_frame_bytes),
            ("max_nnz", self.max_nnz),
            ("max_batch_examples", self.max_batch_examples),
            ("event_threads", self.event_threads),
            ("max_conns", self.max_conns),
        ] {
            if v == 0 {
                return Err(Error::Config(format!("server {name} must be >= 1")));
            }
        }
        if self.io_backend == IoBackend::EventLoop && !cfg!(target_os = "linux") {
            return Err(Error::Config(
                "io_backend event-loop needs epoll (Linux); use threads here".into(),
            ));
        }
        if self.max_nnz > u32::MAX as usize {
            return Err(Error::Config(format!(
                "server max_nnz {} exceeds the wire format's u32 bound {}",
                self.max_nnz,
                u32::MAX
            )));
        }
        if self.max_batch_examples > u16::MAX as usize {
            return Err(Error::Config(format!(
                "server max_batch_examples {} exceeds the wire format's u16 bound {}",
                self.max_batch_examples,
                u16::MAX
            )));
        }
        if let Some(t) = &self.trainer {
            t.validate()?;
        }
        if let Some(b) = &self.brownout {
            b.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_valid() {
        ExperimentConfig::paper_default().validate().unwrap();
    }

    #[test]
    fn json_round_trip() {
        let dir = crate::util::tempdir::TempDir::new("t");
        let p = dir.path().join("exp.json");
        let cfg = ExperimentConfig::paper_default();
        cfg.save(&p).unwrap();
        let back = ExperimentConfig::load(&p).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.pair, cfg.pair);
        assert_eq!(back.policy, cfg.policy);
    }

    #[test]
    fn validation_rejects_bad_fields() {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.lambda = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::paper_default();
        cfg.pair = (3, 3);
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::paper_default();
        cfg.boundary = AnyBoundary::Constant { delta: 1.2, paper_literal: false };
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::paper_default();
        cfg.runs = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn server_config_round_trip_and_defaults() {
        let cfg = ServerConfig {
            listen: "0.0.0.0:9000".into(),
            workers: 8,
            max_batch: 32,
            queue: 4096,
            max_pending_per_conn: 128,
            max_frame_bytes: 1 << 16,
            max_nnz: 2048,
            max_batch_examples: 64,
            seed: 42,
            io_backend: IoBackend::Threads,
            event_threads: 4,
            max_conns: 2_000,
            write_timeout_ms: 5_000,
            idle_timeout_ms: 30_000,
            snapshot_dir: Some(PathBuf::from("/var/lib/attentive/snapshots")),
            trainer: Some(TrainerWireConfig {
                queue: 512,
                publish_every_updates: 32,
                publish_every_ms: 100,
                learner: LearnerKind::Pegasos,
                lambda: 1e-3,
                boundary: AnyBoundary::Constant { delta: 0.05, paper_literal: false },
                policy: CoordinatePolicy::Permuted,
                seed: 9,
            }),
            brownout: Some(BrownoutConfig {
                tighten: 0.6,
                enter: 0.8,
                exit: 0.3,
                dwell_ms: 150,
                sample_ms: 10,
                latency_target_us: 2_000,
            }),
            deadline_default_ms: 250,
        };
        let back = ServerConfig::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, cfg);
        // Sparse document: everything defaults (trainer stays off).
        let sparse = ServerConfig::from_json(&Json::parse(r#"{"workers": 4}"#).unwrap()).unwrap();
        assert_eq!(sparse.workers, 4);
        assert_eq!(sparse.listen, ServerConfig::default().listen);
        assert_eq!(sparse.queue, ServerConfig::default().queue);
        assert_eq!(sparse.max_frame_bytes, 1 << 20);
        assert_eq!(sparse.max_nnz, u16::MAX as usize);
        assert_eq!(sparse.max_batch_examples, 128);
        assert_eq!(sparse.event_threads, 2);
        assert_eq!(sparse.max_conns, 16_384);
        assert_eq!(sparse.write_timeout_ms, 2_000);
        assert_eq!(sparse.idle_timeout_ms, 0);
        assert_eq!(sparse.snapshot_dir, None);
        assert_eq!(sparse.trainer, None);
        assert_eq!(sparse.brownout, None);
        assert_eq!(sparse.deadline_default_ms, 0);
        sparse.validate().unwrap();
    }

    #[test]
    fn brownout_config_round_trip_and_validation() {
        // Empty object: all defaults, and the defaults validate.
        let d = BrownoutConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d, BrownoutConfig::default());
        d.validate().unwrap();
        // Omitted from the server JSON when disabled.
        assert!(!ServerConfig::default().to_json().to_string_compact().contains("brownout"));
        // Round trip through the ServerConfig envelope.
        let cfg = ServerConfig {
            brownout: Some(BrownoutConfig { tighten: 0.4, ..Default::default() }),
            deadline_default_ms: 50,
            ..Default::default()
        };
        let back =
            ServerConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.brownout, cfg.brownout);
        assert_eq!(back.deadline_default_ms, 50);
        // Validation: tighten in (0,1], enter/exit in (0,1] with
        // enter > exit, sample_ms >= 1.
        let b = BrownoutConfig { tighten: 0.0, ..Default::default() };
        assert!(b.validate().is_err());
        let b = BrownoutConfig { tighten: 1.5, ..Default::default() };
        assert!(b.validate().is_err());
        let b = BrownoutConfig { enter: 0.3, exit: 0.3, ..Default::default() };
        assert!(b.validate().is_err(), "degenerate hysteresis band");
        let b = BrownoutConfig { enter: 1.2, ..Default::default() };
        assert!(b.validate().is_err());
        let b = BrownoutConfig { exit: 0.0, ..Default::default() };
        assert!(b.validate().is_err());
        let b = BrownoutConfig { sample_ms: 0, ..Default::default() };
        assert!(b.validate().is_err());
        // A bad nested brownout fails the server-level validate too.
        let cfg = ServerConfig {
            brownout: Some(BrownoutConfig { sample_ms: 0, ..Default::default() }),
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn timeout_and_snapshot_knobs_round_trip_and_zero_means_off() {
        // 0 disables either deadline — explicitly valid, not a zero-knob
        // config error like the structural counts.
        let cfg = ServerConfig { write_timeout_ms: 0, idle_timeout_ms: 0, ..Default::default() };
        cfg.validate().unwrap();
        let back =
            ServerConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.write_timeout_ms, 0);
        assert_eq!(back.idle_timeout_ms, 0);
        // snapshot_dir is omitted from the JSON when unset and round
        // trips as a path when set.
        assert!(!ServerConfig::default().to_json().to_string_compact().contains("snapshot_dir"));
        let cfg =
            ServerConfig { snapshot_dir: Some(PathBuf::from("snaps")), ..Default::default() };
        let back =
            ServerConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.snapshot_dir, Some(PathBuf::from("snaps")));
        cfg.validate().unwrap();
    }

    #[test]
    fn trainer_wire_config_round_trip_and_validation() {
        // Empty object: all defaults, and the defaults validate.
        let d = TrainerWireConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d, TrainerWireConfig::default());
        d.validate().unwrap();
        // Full round trip through the ServerConfig envelope.
        let cfg = ServerConfig {
            trainer: Some(TrainerWireConfig { queue: 7, seed: 3, ..Default::default() }),
            ..Default::default()
        };
        let back =
            ServerConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.trainer, cfg.trainer);
        // Validation: queue >= 1, some cadence, lambda > 0, sane delta,
        // and (for now) pegasos only.
        let t = TrainerWireConfig { queue: 0, ..Default::default() };
        assert!(t.validate().is_err());
        let t = TrainerWireConfig {
            publish_every_updates: 0,
            publish_every_ms: 0,
            ..Default::default()
        };
        assert!(t.validate().is_err());
        let t = TrainerWireConfig { lambda: 0.0, ..Default::default() };
        assert!(t.validate().is_err());
        let t = TrainerWireConfig {
            boundary: AnyBoundary::Constant { delta: 1.5, paper_literal: false },
            ..Default::default()
        };
        assert!(t.validate().is_err());
        let t = TrainerWireConfig { learner: LearnerKind::Perceptron, ..Default::default() };
        assert!(t.validate().is_err());
        // A bad nested trainer fails the server-level validate too.
        let cfg = ServerConfig {
            trainer: Some(TrainerWireConfig { queue: 0, ..Default::default() }),
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn io_backend_names_round_trip_and_gate_validation() {
        assert_eq!(IoBackend::from_name("threads").unwrap(), IoBackend::Threads);
        assert_eq!(IoBackend::from_name("event-loop").unwrap(), IoBackend::EventLoop);
        assert_eq!(IoBackend::from_name("event_loop").unwrap(), IoBackend::EventLoop);
        assert!(IoBackend::from_name("fibers").is_err());
        for backend in [IoBackend::Threads, IoBackend::EventLoop] {
            assert_eq!(IoBackend::from_name(backend.name()).unwrap(), backend);
        }
        // An explicit backend survives the JSON round trip.
        let cfg = ServerConfig { io_backend: IoBackend::EventLoop, ..Default::default() };
        let back =
            ServerConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.io_backend, IoBackend::EventLoop);
        // Unknown backend names are a parse error, not a silent default.
        assert!(ServerConfig::from_json(
            &Json::parse(r#"{"io_backend":"quantum"}"#).unwrap()
        )
        .is_err());
        // Knob sanity: the new counts must be >= 1.
        let cfg = ServerConfig { event_threads: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = ServerConfig { max_conns: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
        #[cfg(target_os = "linux")]
        {
            let cfg = ServerConfig { io_backend: IoBackend::EventLoop, ..Default::default() };
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn io_backend_platform_default_prefers_event_loop_on_linux() {
        // The platform default must always validate — whichever OS this
        // test runs on, `ServerConfig::default()` has to be servable.
        #[cfg(target_os = "linux")]
        assert_eq!(IoBackend::default(), IoBackend::EventLoop);
        #[cfg(not(target_os = "linux"))]
        assert_eq!(IoBackend::default(), IoBackend::Threads);
        let cfg = ServerConfig { io_backend: IoBackend::default(), ..Default::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn max_batch_examples_knob_is_validated_and_round_trips() {
        // Wire bound: the SCORE_BATCH count field is a u16.
        let cfg = ServerConfig { max_batch_examples: u16::MAX as usize, ..Default::default() };
        cfg.validate().unwrap();
        let cfg =
            ServerConfig { max_batch_examples: u16::MAX as usize + 1, ..Default::default() };
        assert!(cfg.validate().is_err(), "batch cap beyond the u16 wire bound");
        let cfg = ServerConfig { max_batch_examples: 0, ..Default::default() };
        assert!(cfg.validate().is_err(), "batch cap must admit at least one example");
        // JSON round trip and sparse default.
        let cfg = ServerConfig { max_batch_examples: 7, ..Default::default() };
        let back =
            ServerConfig::from_json(&Json::parse(&cfg.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.max_batch_examples, 7);
    }

    #[test]
    fn server_config_rejects_protocol_knob_abuse() {
        // The v3 sparse frames carry nnz as u32, so knobs up to that
        // bound are now valid (the legacy u16 frame stays self-bounded).
        let cfg = ServerConfig { max_nnz: u16::MAX as usize + 1, ..Default::default() };
        cfg.validate().unwrap();
        // The over-bound value only exists on 64-bit usize.
        #[cfg(target_pointer_width = "64")]
        {
            let cfg = ServerConfig { max_nnz: u32::MAX as usize + 1, ..Default::default() };
            assert!(cfg.validate().is_err(), "nnz beyond the u32 wire bound");
        }
        let cfg = ServerConfig { max_frame_bytes: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn server_config_validation_rejects_zeroes() {
        let mut cfg = ServerConfig::default();
        cfg.validate().unwrap();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ServerConfig::default();
        cfg.queue = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ServerConfig::default();
        cfg.listen.clear();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn server_config_file_round_trip() {
        let dir = crate::util::tempdir::TempDir::new("srvcfg");
        let p = dir.path().join("server.json");
        let cfg = ServerConfig { listen: "127.0.0.1:0".into(), ..Default::default() };
        cfg.save(&p).unwrap();
        assert_eq!(ServerConfig::load(&p).unwrap(), cfg);
    }

    #[test]
    fn defaults_applied_on_sparse_json() {
        let json = r#"{
            "name": "t",
            "data": {"source": "synth", "seed": 1, "count": 100},
            "pair": [2, 3],
            "learner": "pegasos",
            "boundary": {"kind": "full"},
            "policy": "permuted"
        }"#;
        let cfg =
            ExperimentConfig::from_json(&Json::parse(json).unwrap()).unwrap();
        assert_eq!(cfg.runs, 10);
        assert_eq!(cfg.theta, 1.0);
        assert!((cfg.lambda - 1e-4).abs() < 1e-18);
        cfg.validate().unwrap();
    }
}
