//! Wald's identity and expected-stopping-time bounds (paper Theorem 2).
//!
//! Theorem 2: for a walk with increments bounded by `k`, positive drift
//! `E[X] > 0`, and the Constant STST level `τ = sqrt(var(S_n) log δ^{-1/2})`,
//! Wald's identity `E[S_T] = E[T]·E[X]` plus the overshoot bound
//! `S_T ≤ τ + k` gives
//!
//! ```text
//! E[T] ≤ (τ + k) / E[X]  =  O(sqrt(n))        (var(S_n) = O(n))
//! ```
//!
//! These helpers compute the bound and fit the `c·sqrt(n)` law to
//! empirical stopping times (Figure 2b).

/// Theorem 2's upper bound on the expected stopping time:
/// `(τ + k) / E[X]` with `τ = sqrt(var_sn · log(1/√δ))`.
///
/// Returns `f64::INFINITY` when the drift is non-positive (Wald's bound
/// requires `E[X] > 0`; with zero/negative drift the walk may never cross).
pub fn expected_stopping_time_bound(var_sn: f64, delta: f64, increment_bound: f64, drift: f64) -> f64 {
    if drift <= 0.0 {
        return f64::INFINITY;
    }
    let tau = (var_sn.max(0.0) * super::brownian::log_inv_sqrt(delta)).sqrt();
    (tau + increment_bound) / drift
}

/// Least-squares fit of `E[T](n) ≈ c · sqrt(n)` through the origin.
/// Returns `c` and the R² of the fit in sqrt-space — the Figure 2(b)
/// check that measured stopping times follow the O(√n) law.
pub fn fit_sqrt_law(ns: &[f64], stopping_times: &[f64]) -> (f64, f64) {
    assert_eq!(ns.len(), stopping_times.len());
    assert!(!ns.is_empty());
    // Regress t on x = sqrt(n) with zero intercept: c = Σ x t / Σ x².
    let mut sxt = 0.0;
    let mut sxx = 0.0;
    for (&n, &t) in ns.iter().zip(stopping_times) {
        let x = n.sqrt();
        sxt += x * t;
        sxx += x * x;
    }
    let c = sxt / sxx;
    // R² versus the mean-only model.
    let mean_t = stopping_times.iter().sum::<f64>() / stopping_times.len() as f64;
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for (&n, &t) in ns.iter().zip(stopping_times) {
        let pred = c * n.sqrt();
        ss_res += (t - pred) * (t - pred);
        ss_tot += (t - mean_t) * (t - mean_t);
    }
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    (c, r2)
}

/// Empirical check of Wald's identity `E[S_T] = E[T]·E[X]` over a set of
/// (stopping time, stopped sum) samples with known drift. Returns the
/// relative gap `|E[S_T] − E[T]·drift| / max(1, |E[S_T]|)`.
pub fn wald_identity_gap(stopping_times: &[f64], stopped_sums: &[f64], drift: f64) -> f64 {
    assert_eq!(stopping_times.len(), stopped_sums.len());
    if stopping_times.is_empty() {
        return 0.0;
    }
    let et = stopping_times.iter().sum::<f64>() / stopping_times.len() as f64;
    let es = stopped_sums.iter().sum::<f64>() / stopped_sums.len() as f64;
    (es - et * drift).abs() / es.abs().max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_scales_as_sqrt_n() {
        // var(S_n) = n/3 (uniform features): bound(4n)/bound(n) -> 2.
        let b1 = expected_stopping_time_bound(1000.0 / 3.0, 0.1, 1.0, 0.1);
        let b4 = expected_stopping_time_bound(4000.0 / 3.0, 0.1, 1.0, 0.1);
        let ratio = b4 / b1;
        assert!((ratio - 2.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn bound_infinite_without_drift() {
        assert!(expected_stopping_time_bound(100.0, 0.1, 1.0, 0.0).is_infinite());
        assert!(expected_stopping_time_bound(100.0, 0.1, 1.0, -0.5).is_infinite());
    }

    #[test]
    fn sqrt_fit_recovers_exact_law() {
        let ns: Vec<f64> = [64.0, 256.0, 1024.0, 4096.0].to_vec();
        let ts: Vec<f64> = ns.iter().map(|n| 3.5 * n.sqrt()).collect();
        let (c, r2) = fit_sqrt_law(&ns, &ts);
        assert!((c - 3.5).abs() < 1e-9);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn sqrt_fit_rejects_linear_law() {
        // Times growing linearly in n fit sqrt badly (R² noticeably < 1).
        let ns: Vec<f64> = (1..=8).map(|i| (i * i * 64) as f64).collect();
        let ts: Vec<f64> = ns.iter().map(|n| 0.5 * n).collect();
        let (_, r2) = fit_sqrt_law(&ns, &ts);
        assert!(r2 < 0.95, "r2 {r2}");
    }

    #[test]
    fn wald_gap_zero_for_exact_identity() {
        let ts = [10.0, 20.0, 30.0];
        let drift = 0.25;
        let sums: Vec<f64> = ts.iter().map(|t| t * drift).collect();
        assert!(wald_identity_gap(&ts, &sums, drift) < 1e-12);
    }
}
