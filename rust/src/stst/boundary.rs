//! Stopping boundaries: the [`Boundary`] trait and concrete families.
//!
//! A boundary answers one question for the sequential margin walker: given
//! how far into the evaluation we are (`i` of `n`), the decision threshold
//! `θ`, and the (estimated) total variance `var(S_n)`, at what level `τ_i`
//! should the partial sum trigger an early stop?
//!
//! Four families are provided, matching the paper's evaluation:
//!
//! * [`ConstantBoundary`] — the paper's Constant STST (Theorem 1): flat in
//!   `i`, "error-spending" (aggressive early, strict late).
//! * [`CurvedBoundary`] — the curtailed/conservative prior (paper §3.1).
//! * [`BudgetedBoundary`] — the budgeted-learning baseline (Cesa-Bianchi
//!   et al. 2010 / Reyzin 2010 style): evaluate exactly `k` coordinates,
//!   never stop on evidence. Used as the green curve of Figures 3–4.
//! * [`TrivialBoundary`] — never stops: full Pegasos ("the trivial
//!   boundary, which essentially computes everything", §4.1).


use super::brownian;

/// Context handed to a boundary at each step of a sequential evaluation.
#[derive(Debug, Clone, Copy)]
pub struct StopContext {
    /// Index of the *next* coordinate to be evaluated (1-based count of
    /// coordinates already evaluated).
    pub evaluated: usize,
    /// Total number of coordinates the full evaluation would touch.
    pub total: usize,
    /// Decision threshold θ the full sum will be compared against.
    pub theta: f64,
    /// Estimated variance of the full sum `var(S_n)` (independence
    /// assumption: `Σ w_j² var(x_j)`).
    pub var_sn: f64,
}

/// A stopping boundary for the sequential thresholded sum test.
pub trait Boundary: Send + Sync {
    /// The stopping level `τ_i`: the walker stops as soon as the partial
    /// sum strictly exceeds this value. Return `f64::INFINITY` to never
    /// stop at this step.
    fn level(&self, ctx: &StopContext) -> f64;

    /// Whether this boundary stops on *evidence* (partial sum) at all.
    /// Budgeted/Trivial return `false`: they are baselines that ignore the
    /// partial sum's value.
    fn is_evidence_based(&self) -> bool {
        true
    }

    /// Hard cap on the number of coordinates to evaluate, if any
    /// (budgeted baseline). `None` means "up to `total`".
    fn budget(&self, _ctx: &StopContext) -> Option<usize> {
        None
    }

    /// Short identifier used in metrics/CSV output.
    fn name(&self) -> &'static str;
}

/// The paper's Constant STST boundary (Theorem 1 / eq. 8–10).
///
/// `τ = θ/2 + sqrt(θ²/4 + var(S_n)·log(1/√δ))`, independent of `i`.
/// With `paper_literal = true` the exact form printed in the paper's
/// eq. (10) (`θ + sqrt(...)`, slightly more conservative for θ>0) is used
/// instead; the two coincide at θ = 0.
#[derive(Debug, Clone, Copy)]
pub struct ConstantBoundary {
    /// Target decision-error rate δ ∈ (0, 1).
    pub delta: f64,
    /// Use the paper-literal eq. (10) root instead of the corrected one.
    pub paper_literal: bool,
}

impl ConstantBoundary {
    /// Corrected-algebra constant boundary with decision-error rate `delta`.
    pub fn new(delta: f64) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1), got {delta}");
        Self { delta, paper_literal: false }
    }

    /// Paper-literal eq. (10) variant (used by Algorithm 1 as printed).
    pub fn paper_literal(delta: f64) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1), got {delta}");
        Self { delta, paper_literal: true }
    }
}

impl Boundary for ConstantBoundary {
    fn level(&self, ctx: &StopContext) -> f64 {
        if self.paper_literal {
            brownian::constant_boundary_level_paper(self.delta, ctx.theta, ctx.var_sn)
        } else {
            brownian::constant_boundary_level(self.delta, ctx.theta, ctx.var_sn)
        }
    }

    fn name(&self) -> &'static str {
        if self.paper_literal { "constant-stst(paper)" } else { "constant-stst" }
    }
}

/// The Curved STST — the conservative curtailed boundary of paper §3.1.
///
/// Tracks the remaining-sum envelope:
/// `τ_i = θ + z_{1−δ}·sqrt(var(S_n)·(1 − i/n))`. Constant *conditional*
/// error along the curve ⇒ far higher than the Constant STST early in the
/// walk ⇒ stops fewer walks early (the paper's conservatism critique).
#[derive(Debug, Clone, Copy)]
pub struct CurvedBoundary {
    /// Target decision-error rate δ ∈ (0, 1).
    pub delta: f64,
}

impl CurvedBoundary {
    /// Curved boundary with decision-error rate `delta`.
    pub fn new(delta: f64) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1), got {delta}");
        Self { delta }
    }
}

impl Boundary for CurvedBoundary {
    fn level(&self, ctx: &StopContext) -> f64 {
        if ctx.evaluated >= ctx.total {
            // The full sum is known; the decision is made directly.
            return f64::INFINITY;
        }
        let frac = ctx.evaluated as f64 / ctx.total.max(1) as f64;
        brownian::curved_boundary_level(self.delta, ctx.theta, ctx.var_sn, frac)
    }

    fn name(&self) -> &'static str {
        "curved-stst"
    }
}

/// Budgeted baseline: always evaluate exactly `k` coordinates, then decide
/// from the truncated partial sum. Ignores evidence entirely.
#[derive(Debug, Clone, Copy)]
pub struct BudgetedBoundary {
    /// Number of coordinates to evaluate for every example.
    pub k: usize,
}

impl BudgetedBoundary {
    /// Fixed feature budget of `k` coordinates per example.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "budget must be positive");
        Self { k }
    }
}

impl Boundary for BudgetedBoundary {
    fn level(&self, _ctx: &StopContext) -> f64 {
        f64::INFINITY
    }

    fn is_evidence_based(&self) -> bool {
        false
    }

    fn budget(&self, ctx: &StopContext) -> Option<usize> {
        Some(self.k.min(ctx.total))
    }

    fn name(&self) -> &'static str {
        "budgeted"
    }
}

/// Trivial boundary: never stops — the full computation (vanilla Pegasos).
#[derive(Debug, Clone, Copy, Default)]
pub struct TrivialBoundary;

impl Boundary for TrivialBoundary {
    fn level(&self, _ctx: &StopContext) -> f64 {
        f64::INFINITY
    }

    fn is_evidence_based(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "full"
    }
}

/// Type-erased boundary, for configs that choose the family at runtime.
#[derive(Debug, Clone, PartialEq)]
pub enum AnyBoundary {
    /// Constant STST (Theorem 1).
    Constant {
        /// decision-error rate
        delta: f64,
        /// use paper-literal eq. 10
        paper_literal: bool,
    },
    /// Curved STST (conservative prior).
    Curved {
        /// decision-error rate
        delta: f64,
    },
    /// Fixed feature budget.
    Budgeted {
        /// coordinates per example
        k: usize,
    },
    /// Full evaluation.
    Full,
}

impl AnyBoundary {
    /// Serialize as a tagged JSON object (`{"kind": "constant", ...}`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        match self {
            AnyBoundary::Constant { delta, paper_literal } => Json::obj([
                ("kind", Json::Str("constant".into())),
                ("delta", Json::Num(*delta)),
                ("paper_literal", Json::Bool(*paper_literal)),
            ]),
            AnyBoundary::Curved { delta } => Json::obj([
                ("kind", Json::Str("curved".into())),
                ("delta", Json::Num(*delta)),
            ]),
            AnyBoundary::Budgeted { k } => Json::obj([
                ("kind", Json::Str("budgeted".into())),
                ("k", Json::Num(*k as f64)),
            ]),
            AnyBoundary::Full => Json::obj([("kind", Json::Str("full".into()))]),
        }
    }

    /// Parse the tagged JSON form produced by [`Self::to_json`].
    pub fn from_json(v: &crate::util::json::Json) -> Result<Self, String> {
        let kind = v.get("kind").and_then(|k| k.as_str()).ok_or("boundary: missing kind")?;
        match kind {
            "constant" => Ok(AnyBoundary::Constant {
                delta: v.get("delta").and_then(|d| d.as_f64()).ok_or("constant: missing delta")?,
                paper_literal: v
                    .get("paper_literal")
                    .and_then(|b| b.as_bool())
                    .unwrap_or(false),
            }),
            "curved" => Ok(AnyBoundary::Curved {
                delta: v.get("delta").and_then(|d| d.as_f64()).ok_or("curved: missing delta")?,
            }),
            "budgeted" => Ok(AnyBoundary::Budgeted {
                k: v.get("k").and_then(|k| k.as_usize()).ok_or("budgeted: missing k")?,
            }),
            "full" => Ok(AnyBoundary::Full),
            other => Err(format!("unknown boundary kind {other:?}")),
        }
    }
}

impl Boundary for AnyBoundary {
    fn level(&self, ctx: &StopContext) -> f64 {
        match self {
            AnyBoundary::Constant { delta, paper_literal: false } => {
                ConstantBoundary::new(*delta).level(ctx)
            }
            AnyBoundary::Constant { delta, paper_literal: true } => {
                ConstantBoundary::paper_literal(*delta).level(ctx)
            }
            AnyBoundary::Curved { delta } => CurvedBoundary::new(*delta).level(ctx),
            AnyBoundary::Budgeted { .. } | AnyBoundary::Full => f64::INFINITY,
        }
    }

    fn is_evidence_based(&self) -> bool {
        matches!(self, AnyBoundary::Constant { .. } | AnyBoundary::Curved { .. })
    }

    fn budget(&self, ctx: &StopContext) -> Option<usize> {
        match self {
            AnyBoundary::Budgeted { k } => Some((*k).min(ctx.total)),
            _ => None,
        }
    }

    fn name(&self) -> &'static str {
        match self {
            AnyBoundary::Constant { paper_literal: false, .. } => "constant-stst",
            AnyBoundary::Constant { paper_literal: true, .. } => "constant-stst(paper)",
            AnyBoundary::Curved { .. } => "curved-stst",
            AnyBoundary::Budgeted { .. } => "budgeted",
            AnyBoundary::Full => "full",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(evaluated: usize, total: usize, theta: f64, var_sn: f64) -> StopContext {
        StopContext { evaluated, total, theta, var_sn }
    }

    #[test]
    fn constant_boundary_flat_in_i() {
        let b = ConstantBoundary::new(0.1);
        let l1 = b.level(&ctx(1, 784, 1.0, 50.0));
        let l2 = b.level(&ctx(400, 784, 1.0, 50.0));
        assert_eq!(l1, l2);
        assert!(l1.is_finite() && l1 > 0.0);
    }

    #[test]
    fn constant_vs_curved_early_aggressiveness() {
        // The paper's error-spending argument: early in the walk the
        // constant boundary sits BELOW the curved (curtailed) one, so it
        // stops more walks early; late in the walk the relation flips.
        let c = ConstantBoundary::new(0.1);
        let k = CurvedBoundary::new(0.1);
        let early_c = c.level(&ctx(10, 784, 0.0, 50.0));
        let early_k = k.level(&ctx(10, 784, 0.0, 50.0));
        assert!(early_k > early_c, "curved {early_k} must exceed constant {early_c} early");
        let late_c = c.level(&ctx(780, 784, 0.0, 50.0));
        let late_k = k.level(&ctx(780, 784, 0.0, 50.0));
        assert!(late_k < late_c, "curved {late_k} must drop below constant {late_c} late");
    }

    #[test]
    fn curved_never_stops_at_endpoint() {
        let k = CurvedBoundary::new(0.1);
        assert_eq!(k.level(&ctx(784, 784, 1.0, 50.0)), f64::INFINITY);
        // And approaches theta just before it.
        let near_end = k.level(&ctx(783, 784, 1.0, 50.0));
        assert!(near_end > 1.0 && near_end < 1.5, "near-end level {near_end}");
    }

    #[test]
    fn budgeted_caps_at_k_and_total() {
        let b = BudgetedBoundary::new(49);
        assert_eq!(b.budget(&ctx(0, 784, 1.0, 50.0)), Some(49));
        assert_eq!(b.budget(&ctx(0, 10, 1.0, 50.0)), Some(10));
        assert!(!b.is_evidence_based());
        assert_eq!(b.level(&ctx(5, 784, 1.0, 50.0)), f64::INFINITY);
    }

    #[test]
    fn trivial_never_stops() {
        let t = TrivialBoundary;
        assert_eq!(t.level(&ctx(5, 784, 1.0, 50.0)), f64::INFINITY);
        assert_eq!(t.budget(&ctx(5, 784, 1.0, 50.0)), None);
    }

    #[test]
    fn any_boundary_dispatch_matches_concrete() {
        let c = StopContext { evaluated: 10, total: 784, theta: 1.0, var_sn: 42.0 };
        assert_eq!(
            AnyBoundary::Constant { delta: 0.1, paper_literal: false }.level(&c),
            ConstantBoundary::new(0.1).level(&c)
        );
        assert_eq!(
            AnyBoundary::Curved { delta: 0.1 }.level(&c),
            CurvedBoundary::new(0.1).level(&c)
        );
        assert_eq!(AnyBoundary::Budgeted { k: 3 }.budget(&c), Some(3));
        assert_eq!(AnyBoundary::Full.level(&c), f64::INFINITY);
    }

    #[test]
    fn json_round_trip() {
        for b in [
            AnyBoundary::Constant { delta: 0.1, paper_literal: true },
            AnyBoundary::Curved { delta: 0.05 },
            AnyBoundary::Budgeted { k: 49 },
            AnyBoundary::Full,
        ] {
            let s = b.to_json().to_string_compact();
            let b2 = AnyBoundary::from_json(&crate::util::json::Json::parse(&s).unwrap()).unwrap();
            assert_eq!(b2, b);
        }
        assert!(AnyBoundary::from_json(&crate::util::json::Json::Null).is_err());
    }

    #[test]
    #[should_panic(expected = "delta must be in (0,1)")]
    fn rejects_bad_delta() {
        ConstantBoundary::new(1.5);
    }
}
