//! Stopping boundaries: the [`Boundary`] trait and concrete families.
//!
//! A boundary answers one question for the sequential margin walker: given
//! how far into the evaluation we are (`i` of `n`), the decision threshold
//! `θ`, and the (estimated) total variance `var(S_n)`, at what level `τ_i`
//! should the partial sum trigger an early stop?
//!
//! Four families are provided, matching the paper's evaluation:
//!
//! * [`ConstantBoundary`] — the paper's Constant STST (Theorem 1): flat in
//!   `i`, "error-spending" (aggressive early, strict late).
//! * [`CurvedBoundary`] — the curtailed/conservative prior (paper §3.1).
//! * [`BudgetedBoundary`] — the budgeted-learning baseline (Cesa-Bianchi
//!   et al. 2010 / Reyzin 2010 style): evaluate exactly `k` coordinates,
//!   never stop on evidence. Used as the green curve of Figures 3–4.
//! * [`TrivialBoundary`] — never stops: full Pegasos ("the trivial
//!   boundary, which essentially computes everything", §4.1).


use super::brownian;

/// Context handed to a boundary at each step of a sequential evaluation.
#[derive(Debug, Clone, Copy)]
pub struct StopContext {
    /// Index of the *next* coordinate to be evaluated (1-based count of
    /// coordinates already evaluated).
    pub evaluated: usize,
    /// Total number of coordinates the full evaluation would touch.
    pub total: usize,
    /// Decision threshold θ the full sum will be compared against.
    pub theta: f64,
    /// Estimated variance of the full sum `var(S_n)` (independence
    /// assumption: `Σ w_j² var(x_j)`).
    pub var_sn: f64,
}

/// A stopping boundary for the sequential thresholded sum test.
pub trait Boundary: Send + Sync {
    /// The stopping level `τ_i`: the walker stops as soon as the partial
    /// sum strictly exceeds this value. Return `f64::INFINITY` to never
    /// stop at this step.
    fn level(&self, ctx: &StopContext) -> f64;

    /// Whether this boundary stops on *evidence* (partial sum) at all.
    /// Budgeted/Trivial return `false`: they are baselines that ignore the
    /// partial sum's value.
    fn is_evidence_based(&self) -> bool {
        true
    }

    /// Hard cap on the number of coordinates to evaluate, if any
    /// (budgeted baseline). `None` means "up to `total`".
    fn budget(&self, _ctx: &StopContext) -> Option<usize> {
        None
    }

    /// Short identifier used in metrics/CSV output.
    fn name(&self) -> &'static str;
}

/// The paper's Constant STST boundary (Theorem 1 / eq. 8–10).
///
/// `τ = θ/2 + sqrt(θ²/4 + var(S_n)·log(1/√δ))`, independent of `i`.
/// With `paper_literal = true` the exact form printed in the paper's
/// eq. (10) (`θ + sqrt(...)`, slightly more conservative for θ>0) is used
/// instead; the two coincide at θ = 0.
#[derive(Debug, Clone, Copy)]
pub struct ConstantBoundary {
    /// Target decision-error rate δ ∈ (0, 1).
    pub delta: f64,
    /// Use the paper-literal eq. (10) root instead of the corrected one.
    pub paper_literal: bool,
}

impl ConstantBoundary {
    /// Corrected-algebra constant boundary with decision-error rate `delta`.
    pub fn new(delta: f64) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1), got {delta}");
        Self { delta, paper_literal: false }
    }

    /// Paper-literal eq. (10) variant (used by Algorithm 1 as printed).
    pub fn paper_literal(delta: f64) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1), got {delta}");
        Self { delta, paper_literal: true }
    }
}

impl Boundary for ConstantBoundary {
    fn level(&self, ctx: &StopContext) -> f64 {
        if self.paper_literal {
            brownian::constant_boundary_level_paper(self.delta, ctx.theta, ctx.var_sn)
        } else {
            brownian::constant_boundary_level(self.delta, ctx.theta, ctx.var_sn)
        }
    }

    fn name(&self) -> &'static str {
        if self.paper_literal { "constant-stst(paper)" } else { "constant-stst" }
    }
}

/// The Curved STST — the conservative curtailed boundary of paper §3.1.
///
/// Tracks the remaining-sum envelope:
/// `τ_i = θ + z_{1−δ}·sqrt(var(S_n)·(1 − i/n))`. Constant *conditional*
/// error along the curve ⇒ far higher than the Constant STST early in the
/// walk ⇒ stops fewer walks early (the paper's conservatism critique).
#[derive(Debug, Clone, Copy)]
pub struct CurvedBoundary {
    /// Target decision-error rate δ ∈ (0, 1).
    pub delta: f64,
}

impl CurvedBoundary {
    /// Curved boundary with decision-error rate `delta`.
    pub fn new(delta: f64) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1), got {delta}");
        Self { delta }
    }
}

impl Boundary for CurvedBoundary {
    fn level(&self, ctx: &StopContext) -> f64 {
        if ctx.evaluated >= ctx.total {
            // The full sum is known; the decision is made directly.
            return f64::INFINITY;
        }
        let frac = ctx.evaluated as f64 / ctx.total.max(1) as f64;
        brownian::curved_boundary_level(self.delta, ctx.theta, ctx.var_sn, frac)
    }

    fn name(&self) -> &'static str {
        "curved-stst"
    }
}

/// Budgeted baseline: always evaluate exactly `k` coordinates, then decide
/// from the truncated partial sum. Ignores evidence entirely.
#[derive(Debug, Clone, Copy)]
pub struct BudgetedBoundary {
    /// Number of coordinates to evaluate for every example.
    pub k: usize,
}

impl BudgetedBoundary {
    /// Fixed feature budget of `k` coordinates per example.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "budget must be positive");
        Self { k }
    }
}

impl Boundary for BudgetedBoundary {
    fn level(&self, _ctx: &StopContext) -> f64 {
        f64::INFINITY
    }

    fn is_evidence_based(&self) -> bool {
        false
    }

    fn budget(&self, ctx: &StopContext) -> Option<usize> {
        Some(self.k.min(ctx.total))
    }

    fn name(&self) -> &'static str {
        "budgeted"
    }
}

/// Trivial boundary: never stops — the full computation (vanilla Pegasos).
#[derive(Debug, Clone, Copy, Default)]
pub struct TrivialBoundary;

impl Boundary for TrivialBoundary {
    fn level(&self, _ctx: &StopContext) -> f64 {
        f64::INFINITY
    }

    fn is_evidence_based(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "full"
    }
}

/// Type-erased boundary, for configs that choose the family at runtime.
#[derive(Debug, Clone, PartialEq)]
pub enum AnyBoundary {
    /// Constant STST (Theorem 1).
    Constant {
        /// decision-error rate
        delta: f64,
        /// use paper-literal eq. 10
        paper_literal: bool,
    },
    /// Curved STST (conservative prior).
    Curved {
        /// decision-error rate
        delta: f64,
    },
    /// Fixed feature budget.
    Budgeted {
        /// coordinates per example
        k: usize,
    },
    /// Full evaluation.
    Full,
}

impl AnyBoundary {
    /// Serialize as a tagged JSON object (`{"kind": "constant", ...}`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        match self {
            AnyBoundary::Constant { delta, paper_literal } => Json::obj([
                ("kind", Json::Str("constant".into())),
                ("delta", Json::Num(*delta)),
                ("paper_literal", Json::Bool(*paper_literal)),
            ]),
            AnyBoundary::Curved { delta } => Json::obj([
                ("kind", Json::Str("curved".into())),
                ("delta", Json::Num(*delta)),
            ]),
            AnyBoundary::Budgeted { k } => Json::obj([
                ("kind", Json::Str("budgeted".into())),
                ("k", Json::Num(*k as f64)),
            ]),
            AnyBoundary::Full => Json::obj([("kind", Json::Str("full".into()))]),
        }
    }

    /// Parse the tagged JSON form produced by [`Self::to_json`].
    pub fn from_json(v: &crate::util::json::Json) -> Result<Self, String> {
        let kind = v.get("kind").and_then(|k| k.as_str()).ok_or("boundary: missing kind")?;
        match kind {
            "constant" => Ok(AnyBoundary::Constant {
                delta: v.get("delta").and_then(|d| d.as_f64()).ok_or("constant: missing delta")?,
                paper_literal: v
                    .get("paper_literal")
                    .and_then(|b| b.as_bool())
                    .unwrap_or(false),
            }),
            "curved" => Ok(AnyBoundary::Curved {
                delta: v.get("delta").and_then(|d| d.as_f64()).ok_or("curved: missing delta")?,
            }),
            "budgeted" => Ok(AnyBoundary::Budgeted {
                k: v.get("k").and_then(|k| k.as_usize()).ok_or("budgeted: missing k")?,
            }),
            "full" => Ok(AnyBoundary::Full),
            other => Err(format!("unknown boundary kind {other:?}")),
        }
    }
}

impl Boundary for AnyBoundary {
    fn level(&self, ctx: &StopContext) -> f64 {
        match self {
            AnyBoundary::Constant { delta, paper_literal: false } => {
                ConstantBoundary::new(*delta).level(ctx)
            }
            AnyBoundary::Constant { delta, paper_literal: true } => {
                ConstantBoundary::paper_literal(*delta).level(ctx)
            }
            AnyBoundary::Curved { delta } => CurvedBoundary::new(*delta).level(ctx),
            AnyBoundary::Budgeted { .. } | AnyBoundary::Full => f64::INFINITY,
        }
    }

    fn is_evidence_based(&self) -> bool {
        matches!(self, AnyBoundary::Constant { .. } | AnyBoundary::Curved { .. })
    }

    fn budget(&self, ctx: &StopContext) -> Option<usize> {
        match self {
            AnyBoundary::Budgeted { k } => Some((*k).min(ctx.total)),
            _ => None,
        }
    }

    fn name(&self) -> &'static str {
        match self {
            AnyBoundary::Constant { paper_literal: false, .. } => "constant-stst",
            AnyBoundary::Constant { paper_literal: true, .. } => "constant-stst(paper)",
            AnyBoundary::Curved { .. } => "curved-stst",
            AnyBoundary::Budgeted { .. } => "budgeted",
            AnyBoundary::Full => "full",
        }
    }
}

/// Precomputed stop-threshold table for the serving hot path (θ = 0).
///
/// The level sequence `τ_1..τ_n` of every [`AnyBoundary`] family depends
/// only on `(var_sn, n, δ)` — constant per published snapshot — so the
/// `sqrt`-laden closed forms can be evaluated **once** when a snapshot is
/// installed and the walker compares against stored values instead of
/// recomputing them per feature. Stop decisions are bit-identical by
/// construction: every entry is produced by calling the boundary's own
/// [`Boundary::level`] with the exact [`StopContext`] the scalar walker
/// would have built (`theta = 0.0`, same `evaluated`/`total`/`var_sn`).
///
/// Three internal representations, chosen per family:
///
/// * `Flat` — [`ConstantBoundary`]: the level ignores `evaluated`/`total`
///   entirely, so one `f64` serves every step of every walk length.
/// * `PerStep` — [`CurvedBoundary`]: `τ_i` depends on `i/n`, so the table
///   is valid only for the exact `total` it was built for (see
///   [`Self::supports_total`]; [`TableCache`] handles rebuilds).
/// * `NonEvidence` — budgeted/full baselines: no level is ever consulted,
///   only the evaluation cap.
#[derive(Debug, Clone)]
pub struct BoundaryTable {
    kind: TableKind,
    total: usize,
}

#[derive(Debug, Clone)]
enum TableKind {
    /// Same τ at every step and for any walk length (Constant STST).
    Flat(f64),
    /// `levels[i]` is `τ_{i+1}`; valid only for walks of exactly `total`.
    PerStep(Vec<f64>),
    /// Never stops on evidence; `budget` caps the walk (budgeted baseline).
    NonEvidence { budget: Option<usize> },
}

impl BoundaryTable {
    /// Build the table for `boundary` at prediction time (θ = 0) with the
    /// snapshot's variance estimate and an expected walk length `total`
    /// (`dim` for dense scoring; support size for sparse).
    pub fn for_boundary(boundary: &AnyBoundary, var_sn: f64, total: usize) -> Self {
        let kind = match boundary {
            AnyBoundary::Constant { .. } => {
                // Flat in `evaluated` and `total`: any context yields τ.
                let ctx = StopContext { evaluated: 1, total: total.max(1), theta: 0.0, var_sn };
                TableKind::Flat(boundary.level(&ctx))
            }
            AnyBoundary::Curved { .. } => TableKind::PerStep(
                (1..=total)
                    .map(|i| {
                        boundary.level(&StopContext { evaluated: i, total, theta: 0.0, var_sn })
                    })
                    .collect(),
            ),
            AnyBoundary::Budgeted { k } => TableKind::NonEvidence { budget: Some(*k) },
            AnyBoundary::Full => TableKind::NonEvidence { budget: None },
        };
        Self { kind, total }
    }

    /// [`Self::for_boundary`] with every stop level tightened by
    /// `tighten ∈ (0, 1]` — the brownout degradation lever. A tightened
    /// table stops walks **no later** than the plain one: evidence
    /// levels are scaled down multiplicatively (`τ_i · tighten`), and
    /// the budgeted baseline's cap shrinks to `max(1, ⌊k · tighten⌋)`.
    /// The full boundary is exempt (there is no level to tighten; a
    /// "never stop" baseline stays a never-stop baseline under
    /// brownout). `tighten = 1.0` delegates to the plain constructor,
    /// so a tier-0 table is bit-identical to the undegraded path.
    pub fn for_boundary_scaled(
        boundary: &AnyBoundary,
        var_sn: f64,
        total: usize,
        tighten: f64,
    ) -> Self {
        assert!(
            tighten > 0.0 && tighten <= 1.0,
            "tighten must be in (0,1], got {tighten}"
        );
        if tighten == 1.0 {
            return Self::for_boundary(boundary, var_sn, total);
        }
        let mut table = Self::for_boundary(boundary, var_sn, total);
        match &mut table.kind {
            TableKind::Flat(tau) => *tau *= tighten,
            TableKind::PerStep(levels) => {
                // INFINITY entries (curved endpoint) stay INFINITY.
                for tau in levels.iter_mut() {
                    *tau *= tighten;
                }
            }
            TableKind::NonEvidence { budget } => {
                if let Some(k) = budget {
                    *k = ((*k as f64 * tighten).floor() as usize).max(1);
                }
            }
        }
        table
    }

    /// Whether this table is valid for a walk of `total` coordinates.
    /// Only the per-step (curved) representation is length-specific.
    pub fn supports_total(&self, total: usize) -> bool {
        match &self.kind {
            TableKind::PerStep(_) => total == self.total,
            _ => true,
        }
    }

    /// Whether the underlying boundary stops on evidence at all.
    pub fn is_evidence_based(&self) -> bool {
        !matches!(self.kind, TableKind::NonEvidence { .. })
    }

    /// Number of coordinates a walk of `total` evaluates at most —
    /// `min(k, total)` for the budgeted baseline, `total` otherwise.
    pub fn cap(&self, total: usize) -> usize {
        match &self.kind {
            TableKind::NonEvidence { budget: Some(k) } => (*k).min(total),
            _ => total,
        }
    }

    /// The stop level `τ_evaluated` (`evaluated` is the 1-based count of
    /// coordinates already summed, exactly as in [`StopContext`]).
    #[inline]
    pub fn level_at(&self, evaluated: usize) -> f64 {
        match &self.kind {
            TableKind::Flat(tau) => *tau,
            TableKind::PerStep(levels) => levels[evaluated - 1],
            TableKind::NonEvidence { .. } => f64::INFINITY,
        }
    }

    /// The single level shared by every step, if the boundary is flat —
    /// lets the kernel hoist the comparison value out of the walk loop.
    #[inline]
    pub fn flat_level(&self) -> Option<f64> {
        match &self.kind {
            TableKind::Flat(tau) => Some(*tau),
            _ => None,
        }
    }
}

/// A [`BoundaryTable`] that rebuilds itself when the walk length changes.
///
/// Serving workers hold one of these per model/voter: flat (constant) and
/// non-evidence tables never rebuild; a curved table rebuilds only when a
/// request's walk length differs from the previous one (dense requests all
/// share `total = dim`, so they build exactly once — sparse requests
/// rebuild per distinct support size, the documented cost of the curved
/// family on sparse traffic).
#[derive(Debug, Clone)]
pub struct TableCache {
    boundary: AnyBoundary,
    var_sn: f64,
    /// Brownout tightening factor applied to every (re)build; `1.0`
    /// means the plain, bit-identical construction path.
    tighten: f64,
    table: BoundaryTable,
}

impl TableCache {
    /// Cache seeded for walks of `total` coordinates.
    pub fn new(boundary: AnyBoundary, var_sn: f64, total: usize) -> Self {
        Self::new_scaled(boundary, var_sn, total, 1.0)
    }

    /// [`Self::new`] with a brownout tightening factor (see
    /// [`BoundaryTable::for_boundary_scaled`]); rebuilds for new walk
    /// lengths re-apply the same factor.
    pub fn new_scaled(boundary: AnyBoundary, var_sn: f64, total: usize, tighten: f64) -> Self {
        let table = BoundaryTable::for_boundary_scaled(&boundary, var_sn, total, tighten);
        Self { boundary, var_sn, tighten, table }
    }

    /// The table for a walk of `total` coordinates, rebuilding if needed.
    #[inline]
    pub fn for_total(&mut self, total: usize) -> &BoundaryTable {
        if !self.table.supports_total(total) {
            self.table = BoundaryTable::for_boundary_scaled(
                &self.boundary,
                self.var_sn,
                total,
                self.tighten,
            );
        }
        &self.table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(evaluated: usize, total: usize, theta: f64, var_sn: f64) -> StopContext {
        StopContext { evaluated, total, theta, var_sn }
    }

    #[test]
    fn constant_boundary_flat_in_i() {
        let b = ConstantBoundary::new(0.1);
        let l1 = b.level(&ctx(1, 784, 1.0, 50.0));
        let l2 = b.level(&ctx(400, 784, 1.0, 50.0));
        assert_eq!(l1, l2);
        assert!(l1.is_finite() && l1 > 0.0);
    }

    #[test]
    fn constant_vs_curved_early_aggressiveness() {
        // The paper's error-spending argument: early in the walk the
        // constant boundary sits BELOW the curved (curtailed) one, so it
        // stops more walks early; late in the walk the relation flips.
        let c = ConstantBoundary::new(0.1);
        let k = CurvedBoundary::new(0.1);
        let early_c = c.level(&ctx(10, 784, 0.0, 50.0));
        let early_k = k.level(&ctx(10, 784, 0.0, 50.0));
        assert!(early_k > early_c, "curved {early_k} must exceed constant {early_c} early");
        let late_c = c.level(&ctx(780, 784, 0.0, 50.0));
        let late_k = k.level(&ctx(780, 784, 0.0, 50.0));
        assert!(late_k < late_c, "curved {late_k} must drop below constant {late_c} late");
    }

    #[test]
    fn curved_never_stops_at_endpoint() {
        let k = CurvedBoundary::new(0.1);
        assert_eq!(k.level(&ctx(784, 784, 1.0, 50.0)), f64::INFINITY);
        // And approaches theta just before it.
        let near_end = k.level(&ctx(783, 784, 1.0, 50.0));
        assert!(near_end > 1.0 && near_end < 1.5, "near-end level {near_end}");
    }

    #[test]
    fn budgeted_caps_at_k_and_total() {
        let b = BudgetedBoundary::new(49);
        assert_eq!(b.budget(&ctx(0, 784, 1.0, 50.0)), Some(49));
        assert_eq!(b.budget(&ctx(0, 10, 1.0, 50.0)), Some(10));
        assert!(!b.is_evidence_based());
        assert_eq!(b.level(&ctx(5, 784, 1.0, 50.0)), f64::INFINITY);
    }

    #[test]
    fn trivial_never_stops() {
        let t = TrivialBoundary;
        assert_eq!(t.level(&ctx(5, 784, 1.0, 50.0)), f64::INFINITY);
        assert_eq!(t.budget(&ctx(5, 784, 1.0, 50.0)), None);
    }

    #[test]
    fn any_boundary_dispatch_matches_concrete() {
        let c = StopContext { evaluated: 10, total: 784, theta: 1.0, var_sn: 42.0 };
        assert_eq!(
            AnyBoundary::Constant { delta: 0.1, paper_literal: false }.level(&c),
            ConstantBoundary::new(0.1).level(&c)
        );
        assert_eq!(
            AnyBoundary::Curved { delta: 0.1 }.level(&c),
            CurvedBoundary::new(0.1).level(&c)
        );
        assert_eq!(AnyBoundary::Budgeted { k: 3 }.budget(&c), Some(3));
        assert_eq!(AnyBoundary::Full.level(&c), f64::INFINITY);
    }

    #[test]
    fn json_round_trip() {
        for b in [
            AnyBoundary::Constant { delta: 0.1, paper_literal: true },
            AnyBoundary::Curved { delta: 0.05 },
            AnyBoundary::Budgeted { k: 49 },
            AnyBoundary::Full,
        ] {
            let s = b.to_json().to_string_compact();
            let b2 = AnyBoundary::from_json(&crate::util::json::Json::parse(&s).unwrap()).unwrap();
            assert_eq!(b2, b);
        }
        assert!(AnyBoundary::from_json(&crate::util::json::Json::Null).is_err());
    }

    #[test]
    #[should_panic(expected = "delta must be in (0,1)")]
    fn rejects_bad_delta() {
        ConstantBoundary::new(1.5);
    }

    #[test]
    fn boundary_table_is_bit_identical_to_the_closed_form() {
        // The serving LUT must reproduce Boundary::level exactly — no
        // tolerance — for every family, across lengths and variances,
        // at the θ = 0 prediction-time context the workers use.
        let families = [
            AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            AnyBoundary::Constant { delta: 0.01, paper_literal: true },
            AnyBoundary::Curved { delta: 0.05 },
            AnyBoundary::Budgeted { k: 7 },
            AnyBoundary::Full,
        ];
        for boundary in &families {
            for &n in &[1usize, 2, 16, 49, 784] {
                for &var_sn in &[0.0, 1.0, 42.5, 1e6] {
                    let table = BoundaryTable::for_boundary(boundary, var_sn, n);
                    for i in 1..=n {
                        let want = boundary
                            .level(&StopContext { evaluated: i, total: n, theta: 0.0, var_sn });
                        let got = table.level_at(i);
                        // Exact f64 equality — bit-identical stop decisions.
                        assert_eq!(
                            got,
                            want,
                            "{} n={n} var={var_sn} i={i}",
                            boundary.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn boundary_table_caps_match_budget_semantics() {
        let full = BoundaryTable::for_boundary(&AnyBoundary::Full, 1.0, 784);
        assert!(!full.is_evidence_based());
        assert_eq!(full.cap(784), 784);
        assert_eq!(full.cap(10), 10);
        assert_eq!(full.level_at(5), f64::INFINITY);

        let budgeted = BoundaryTable::for_boundary(&AnyBoundary::Budgeted { k: 49 }, 1.0, 784);
        assert!(!budgeted.is_evidence_based());
        assert_eq!(budgeted.cap(784), 49, "budget caps long walks");
        assert_eq!(budgeted.cap(10), 10, "short walks cap at their length");

        let constant = BoundaryTable::for_boundary(
            &AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            50.0,
            784,
        );
        assert!(constant.is_evidence_based());
        assert_eq!(constant.cap(784), 784);
        assert_eq!(constant.flat_level(), Some(constant.level_at(1)));
        assert!(constant.supports_total(3), "flat tables serve any length");

        let curved = BoundaryTable::for_boundary(&AnyBoundary::Curved { delta: 0.1 }, 50.0, 784);
        assert!(curved.supports_total(784));
        assert!(!curved.supports_total(783), "per-step tables are length-specific");
        assert_eq!(curved.flat_level(), None);
        assert_eq!(curved.level_at(784), f64::INFINITY, "curved never stops at the endpoint");
    }

    #[test]
    fn scaled_tables_tighten_levels_and_budgets() {
        // tighten = 1.0 is the identity: bit-identical to the plain
        // constructor for every family (the brownout tier-0 guarantee).
        let families = [
            AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            AnyBoundary::Curved { delta: 0.05 },
            AnyBoundary::Budgeted { k: 7 },
            AnyBoundary::Full,
        ];
        for boundary in &families {
            let plain = BoundaryTable::for_boundary(boundary, 42.5, 49);
            let unit = BoundaryTable::for_boundary_scaled(boundary, 42.5, 49, 1.0);
            for i in 1..=49 {
                assert_eq!(unit.level_at(i), plain.level_at(i), "{}", boundary.name());
            }
            assert_eq!(unit.cap(49), plain.cap(49));
        }

        // tighten < 1.0 lowers every finite evidence level...
        let c = AnyBoundary::Constant { delta: 0.1, paper_literal: false };
        let plain = BoundaryTable::for_boundary(&c, 50.0, 784);
        let tight = BoundaryTable::for_boundary_scaled(&c, 50.0, 784, 0.5);
        assert_eq!(tight.level_at(1), plain.level_at(1) * 0.5);
        assert_eq!(tight.flat_level(), Some(plain.level_at(1) * 0.5));

        let k = AnyBoundary::Curved { delta: 0.1 };
        let plain = BoundaryTable::for_boundary(&k, 50.0, 64);
        let tight = BoundaryTable::for_boundary_scaled(&k, 50.0, 64, 0.5);
        for i in 1..64 {
            assert!(tight.level_at(i) <= plain.level_at(i), "i={i}");
        }
        // ...but the curved endpoint sentinel stays INFINITY.
        assert_eq!(tight.level_at(64), f64::INFINITY);

        // Budgeted: the cap shrinks, floored at one coordinate.
        let b = AnyBoundary::Budgeted { k: 49 };
        let tight = BoundaryTable::for_boundary_scaled(&b, 1.0, 784, 0.5);
        assert_eq!(tight.cap(784), 24);
        let floor = BoundaryTable::for_boundary_scaled(&AnyBoundary::Budgeted { k: 1 }, 1.0, 784, 0.1);
        assert_eq!(floor.cap(784), 1, "budget never shrinks below one coordinate");

        // Full stays a never-stop baseline.
        let full = BoundaryTable::for_boundary_scaled(&AnyBoundary::Full, 1.0, 784, 0.25);
        assert_eq!(full.cap(784), 784);
        assert_eq!(full.level_at(5), f64::INFINITY);

        // A scaled cache re-applies its factor on length rebuilds.
        let mut cache = TableCache::new_scaled(AnyBoundary::Curved { delta: 0.1 }, 4.0, 784, 0.5);
        let rebuilt = cache.for_total(32);
        let fresh = BoundaryTable::for_boundary_scaled(&AnyBoundary::Curved { delta: 0.1 }, 4.0, 32, 0.5);
        for i in 1..=32 {
            assert_eq!(rebuilt.level_at(i), fresh.level_at(i));
        }
    }

    #[test]
    #[should_panic(expected = "tighten must be in (0,1]")]
    fn scaled_table_rejects_bad_factor() {
        BoundaryTable::for_boundary_scaled(&AnyBoundary::Full, 1.0, 8, 0.0);
    }

    #[test]
    fn table_cache_rebuilds_only_when_the_length_changes() {
        // Flat: one build serves every length.
        let mut flat =
            TableCache::new(AnyBoundary::Constant { delta: 0.1, paper_literal: false }, 4.0, 784);
        let tau = flat.for_total(784).level_at(1);
        assert_eq!(flat.for_total(12).level_at(1), tau);

        // Curved: the cache transparently rebuilds for a new length and
        // the rebuilt entries still match the closed form exactly.
        let boundary = AnyBoundary::Curved { delta: 0.1 };
        let mut curved = TableCache::new(boundary.clone(), 4.0, 784);
        assert!(curved.for_total(784).supports_total(784));
        let rebuilt = curved.for_total(32);
        assert!(rebuilt.supports_total(32));
        for i in 1..=32 {
            assert_eq!(
                rebuilt.level_at(i),
                boundary.level(&StopContext { evaluated: i, total: 32, theta: 0.0, var_sn: 4.0 })
            );
        }
    }
}
