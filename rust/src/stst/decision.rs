//! Decision-error accounting.
//!
//! The paper's quality criterion is not classification error but
//! **decision error**: the event that the test stopped an example early
//! (declared it unimportant) when its *full* margin would in fact have
//! landed below the threshold θ (i.e. the learner should have updated).
//! Figure 2(a) validates that the empirical decision-error rate matches
//! the Brownian-bridge prediction; this module provides the audit
//! machinery used both there and by the trainer's `--audit` mode, which
//! finishes every stopped evaluation out-of-band to measure the true rate.


/// Outcome of one sequential evaluation, as seen by the audit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalOutcome {
    /// Ran to completion; full margin was below θ (important example).
    FullBelow,
    /// Ran to completion; full margin was ≥ θ (unimportant example).
    FullAbove,
    /// Stopped early; the audited full margin would have been below θ —
    /// **a decision error**.
    StoppedBelow,
    /// Stopped early; the audited full margin would have been ≥ θ —
    /// a correct, computation-saving stop.
    StoppedAbove,
}

/// Aggregates decision outcomes into the rates the paper reports.
#[derive(Debug, Clone, Copy, Default)]
pub struct DecisionAudit {
    full_below: u64,
    full_above: u64,
    stopped_below: u64,
    stopped_above: u64,
}

impl DecisionAudit {
    /// Empty audit.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one outcome.
    pub fn record(&mut self, o: EvalOutcome) {
        match o {
            EvalOutcome::FullBelow => self.full_below += 1,
            EvalOutcome::FullAbove => self.full_above += 1,
            EvalOutcome::StoppedBelow => self.stopped_below += 1,
            EvalOutcome::StoppedAbove => self.stopped_above += 1,
        }
    }

    /// Total evaluations seen.
    pub fn total(&self) -> u64 {
        self.full_below + self.full_above + self.stopped_below + self.stopped_above
    }

    /// Number of early stops (correct or not).
    pub fn stopped(&self) -> u64 {
        self.stopped_below + self.stopped_above
    }

    /// Decision errors: stops on examples that were actually important.
    pub fn errors(&self) -> u64 {
        self.stopped_below
    }

    /// Important examples: those whose full sum was/would be below θ.
    pub fn important(&self) -> u64 {
        self.full_below + self.stopped_below
    }

    /// The paper's conditional decision-error rate, eq. (3):
    /// `P(stopped before n | S_n < θ)` — errors over *important* examples.
    /// This is the quantity the Constant STST bounds by δ.
    pub fn conditional_error_rate(&self) -> f64 {
        let important = self.full_below + self.stopped_below;
        if important == 0 {
            0.0
        } else {
            self.stopped_below as f64 / important as f64
        }
    }

    /// Unconditional early-stop rate `P(stop)` — the computation saving.
    pub fn stop_rate(&self) -> f64 {
        let t = self.total();
        if t == 0 { 0.0 } else { self.stopped() as f64 / t as f64 }
    }

    /// The curtailed conditional `P(S_n < θ | stop)` — eq. (2), the
    /// quantity the *Curved* STST controls. Reported for comparison.
    pub fn curtailed_error_rate(&self) -> f64 {
        let s = self.stopped();
        if s == 0 { 0.0 } else { self.stopped_below as f64 / s as f64 }
    }

    /// Merge a shard's audit (parallel training / simulation).
    pub fn merge(&mut self, other: &DecisionAudit) {
        self.full_below += other.full_below;
        self.full_above += other.full_above;
        self.stopped_below += other.stopped_below;
        self.stopped_above += other.stopped_above;
    }

    /// Verify Bayes consistency (paper eq. 1):
    /// `P(stop|S_n<θ)·P(S_n<θ) = P(S_n<θ|stop)·P(stop)`. Both sides equal
    /// `stopped_below / total`; returns the (tiny) numerical gap, which is
    /// exactly 0 for counts — kept as a sanity method used in tests.
    pub fn bayes_identity_gap(&self) -> f64 {
        let t = self.total() as f64;
        if t == 0.0 {
            return 0.0;
        }
        let important = (self.full_below + self.stopped_below) as f64;
        let lhs = self.conditional_error_rate() * (important / t);
        let rhs = self.curtailed_error_rate() * self.stop_rate();
        (lhs - rhs).abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn audit(fb: u64, fa: u64, sb: u64, sa: u64) -> DecisionAudit {
        let mut a = DecisionAudit::new();
        for _ in 0..fb {
            a.record(EvalOutcome::FullBelow);
        }
        for _ in 0..fa {
            a.record(EvalOutcome::FullAbove);
        }
        for _ in 0..sb {
            a.record(EvalOutcome::StoppedBelow);
        }
        for _ in 0..sa {
            a.record(EvalOutcome::StoppedAbove);
        }
        a
    }

    #[test]
    fn rates_basic() {
        let a = audit(90, 500, 10, 400);
        assert_eq!(a.total(), 1000);
        assert_eq!(a.stopped(), 410);
        assert_eq!(a.errors(), 10);
        // conditional: 10 errors out of 100 important
        assert!((a.conditional_error_rate() - 0.1).abs() < 1e-12);
        assert!((a.stop_rate() - 0.41).abs() < 1e-12);
        assert!((a.curtailed_error_rate() - 10.0 / 410.0).abs() < 1e-12);
    }

    #[test]
    fn empty_audit_is_zero() {
        let a = DecisionAudit::new();
        assert_eq!(a.conditional_error_rate(), 0.0);
        assert_eq!(a.stop_rate(), 0.0);
        assert_eq!(a.curtailed_error_rate(), 0.0);
    }

    #[test]
    fn bayes_identity_holds_exactly() {
        for (fb, fa, sb, sa) in [(90, 500, 10, 400), (1, 1, 1, 1), (0, 10, 0, 5), (7, 0, 3, 0)] {
            assert!(audit(fb, fa, sb, sa).bayes_identity_gap() < 1e-12);
        }
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = audit(1, 2, 3, 4);
        a.merge(&audit(10, 20, 30, 40));
        assert_eq!(a.total(), 110);
        assert_eq!(a.errors(), 33);
    }
}
