//! Online variance estimation for the STST boundary.
//!
//! The Constant STST needs `var(S_n) = var(Σ w_j x_j)`. Under the paper's
//! §4 independence assumption this is `Σ_j w_j² var(x_j)`, where
//! `var(x_j)` is the *class-conditional* variance of feature `j`
//! (Algorithm 1 tracks `var_{y^l}(x_j)` — one estimate per label). We
//! track per-(class, feature) first/second moments with Welford's
//! algorithm, updated only on coordinates the walker actually evaluated
//! (line "Update var_{y^l}(x_j), j = 1..i" of Algorithm 1).
//!
//! Because weights change every Pegasos step, `Σ w_j² var(x_j)` cannot be
//! cached across examples; the evaluator instead folds `w_j²·var̂(x_j)`
//! into a prefix alongside the partial sum so the boundary is O(1) per
//! coordinate (see [`crate::margin::walker`]).


/// Welford online mean/variance for a single scalar stream.
#[derive(Debug, Clone, Copy, Default)]
pub struct OnlineVariance {
    count: u64,
    mean: f64,
    m2: f64,
}

impl OnlineVariance {
    /// Fresh estimator with no observations.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation in.
    #[inline]
    pub fn update(&mut self, x: f64) {
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Running mean (0 before any observation).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (uses `n`, not `n-1`: we want a plug-in
    /// estimate for the boundary, and early robustness matters more than
    /// unbiasedness). Returns the prior `prior_var` until two observations
    /// arrive.
    #[inline]
    pub fn variance_or(&self, prior_var: f64) -> f64 {
        if self.count < 2 {
            prior_var
        } else {
            self.m2 / self.count as f64
        }
    }

    /// Population variance, 0 before two observations.
    pub fn variance(&self) -> f64 {
        self.variance_or(0.0)
    }

    /// Merge another estimator into this one (parallel Welford / Chan).
    pub fn merge(&mut self, other: &OnlineVariance) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = *other;
            return;
        }
        let n1 = self.count as f64;
        let n2 = other.count as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.count += other.count;
    }
}

/// Per-class, per-feature variance table: `var_y(x_j)` for y ∈ {−1, +1}.
///
/// The prior variance used before a feature has ≥2 observations defaults
/// to the variance of a uniform variable on `[-1, 1]` (1/3), matching the
/// paper's `X_i ∈ [−1,1]` normalization — conservative (large τ, stops
/// late) while estimates warm up.
#[derive(Debug, Clone)]
pub struct ClassVariance {
    dim: usize,
    prior_var: f64,
    pos: Vec<OnlineVariance>,
    neg: Vec<OnlineVariance>,
}

impl ClassVariance {
    /// Default prior variance: uniform on [-1, 1].
    pub const DEFAULT_PRIOR: f64 = 1.0 / 3.0;

    /// New table for `dim` features with the default prior.
    pub fn new(dim: usize) -> Self {
        Self::with_prior(dim, Self::DEFAULT_PRIOR)
    }

    /// New table with an explicit warm-up prior variance.
    pub fn with_prior(dim: usize, prior_var: f64) -> Self {
        Self {
            dim,
            prior_var,
            pos: vec![OnlineVariance::default(); dim],
            neg: vec![OnlineVariance::default(); dim],
        }
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    fn side(&self, label: f64) -> &[OnlineVariance] {
        if label >= 0.0 { &self.pos } else { &self.neg }
    }

    fn side_mut(&mut self, label: f64) -> &mut [OnlineVariance] {
        if label >= 0.0 { &mut self.pos } else { &mut self.neg }
    }

    /// Record that feature `j` of an example with `label` had value `x`.
    #[inline]
    pub fn observe(&mut self, label: f64, j: usize, x: f64) {
        self.side_mut(label)[j].update(x);
    }

    /// Record the first `upto` coordinates of an evaluated example —
    /// exactly Algorithm 1's "Update var_{y}(x_j), j = 1, ..., i".
    /// `order[k]` is the feature index evaluated at step `k`.
    pub fn observe_prefix(&mut self, label: f64, order: &[usize], xs: &[f64], upto: usize) {
        let side = self.side_mut(label);
        for &j in order.iter().take(upto) {
            side[j].update(xs[j]);
        }
    }

    /// Class-conditional variance estimate for feature `j` under `label`.
    #[inline]
    pub fn var(&self, label: f64, j: usize) -> f64 {
        self.side(label)[j].variance_or(self.prior_var)
    }

    /// `var(S_n) = Σ_j w_j² var_y(x_j)` — the full-sum variance the
    /// Constant STST plugs into Theorem 1 (independence assumption).
    pub fn sum_variance(&self, label: f64, weights: &[f64]) -> f64 {
        debug_assert_eq!(weights.len(), self.dim);
        let side = self.side(label);
        weights
            .iter()
            .zip(side.iter())
            .map(|(w, v)| w * w * v.variance_or(self.prior_var))
            .sum()
    }

    /// Paper-literal variant: Algorithm 1 prints `Σ_j w_j · var_y(x_j)`
    /// (no square). Exposed for the ablation bench; can go negative for
    /// negative weights, so it is clamped at 0.
    pub fn sum_variance_paper(&self, label: f64, weights: &[f64]) -> f64 {
        debug_assert_eq!(weights.len(), self.dim);
        let side = self.side(label);
        weights
            .iter()
            .zip(side.iter())
            .map(|(w, v)| w * v.variance_or(self.prior_var))
            .sum::<f64>()
            .max(0.0)
    }

    /// Per-feature `w_j² var_y(x_j)` terms, in *feature index* order —
    /// used by the walker to maintain the variance prefix incrementally.
    pub fn weighted_terms(&self, label: f64, weights: &[f64], out: &mut Vec<f64>) {
        debug_assert_eq!(weights.len(), self.dim);
        let side = self.side(label);
        out.clear();
        out.extend(
            weights
                .iter()
                .zip(side.iter())
                .map(|(w, v)| w * w * v.variance_or(self.prior_var)),
        );
    }

    /// Merge a peer table (parallel training shards).
    pub fn merge(&mut self, other: &ClassVariance) {
        assert_eq!(self.dim, other.dim, "merging variance tables of different dims");
        for (a, b) in self.pos.iter_mut().zip(other.pos.iter()) {
            a.merge(b);
        }
        for (a, b) in self.neg.iter_mut().zip(other.neg.iter()) {
            a.merge(b);
        }
    }

    /// Total observations across both classes (for diagnostics).
    pub fn total_observations(&self) -> u64 {
        self.pos.iter().chain(self.neg.iter()).map(|v| v.count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_pass_var(xs: &[f64]) -> f64 {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n
    }

    #[test]
    fn welford_matches_two_pass() {
        let xs = [1.0, 2.0, -0.5, 3.25, 0.0, -2.0, 10.0];
        let mut ov = OnlineVariance::new();
        for &x in &xs {
            ov.update(x);
        }
        let tp = two_pass_var(&xs);
        assert!((ov.variance() - tp).abs() < 1e-12, "{} vs {}", ov.variance(), tp);
        assert!((ov.mean() - xs.iter().sum::<f64>() / xs.len() as f64).abs() < 1e-12);
    }

    #[test]
    fn welford_prior_until_two_observations() {
        let mut ov = OnlineVariance::new();
        assert_eq!(ov.variance_or(0.5), 0.5);
        ov.update(3.0);
        assert_eq!(ov.variance_or(0.5), 0.5);
        ov.update(5.0);
        assert!((ov.variance_or(0.5) - 1.0).abs() < 1e-12); // pop var of {3,5}
    }

    #[test]
    fn merge_equals_concatenation() {
        let xs: Vec<f64> = (0..17).map(|i| (i as f64 * 0.7).sin() * 3.0).collect();
        let (a, b) = xs.split_at(6);
        let mut oa = OnlineVariance::new();
        let mut ob = OnlineVariance::new();
        a.iter().for_each(|&x| oa.update(x));
        b.iter().for_each(|&x| ob.update(x));
        oa.merge(&ob);
        let mut all = OnlineVariance::new();
        xs.iter().for_each(|&x| all.update(x));
        assert!((oa.variance() - all.variance()).abs() < 1e-10);
        assert!((oa.mean() - all.mean()).abs() < 1e-12);
        assert_eq!(oa.count(), all.count());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = OnlineVariance::new();
        a.update(1.0);
        a.update(2.0);
        let before = a;
        a.merge(&OnlineVariance::new());
        assert_eq!(a.count(), before.count());
        let mut empty = OnlineVariance::new();
        empty.merge(&before);
        assert_eq!(empty.count(), 2);
    }

    #[test]
    fn class_conditional_separation() {
        let mut cv = ClassVariance::new(2);
        // pos class: feature 0 constant, feature 1 varies
        for x in [1.0, 1.0, 1.0] {
            cv.observe(1.0, 0, x);
        }
        for x in [0.0, 2.0, -2.0] {
            cv.observe(1.0, 1, x);
        }
        // neg class: the mirror
        for x in [0.0, 4.0] {
            cv.observe(-1.0, 0, x);
        }
        assert!(cv.var(1.0, 0) < 1e-12);
        assert!(cv.var(1.0, 1) > 1.0);
        assert!((cv.var(-1.0, 0) - 4.0).abs() < 1e-12);
        // neg feature 1 unobserved -> prior
        assert!((cv.var(-1.0, 1) - ClassVariance::DEFAULT_PRIOR).abs() < 1e-12);
    }

    #[test]
    fn sum_variance_weights_squared() {
        let mut cv = ClassVariance::with_prior(3, 0.0);
        for (j, vals) in [[0.0f64, 2.0], [1.0, 3.0], [5.0, 5.0]].iter().enumerate() {
            for &x in vals {
                cv.observe(1.0, j, x);
            }
        }
        // pop vars: 1.0, 1.0, 0.0
        let w = [2.0, -3.0, 100.0];
        let v = cv.sum_variance(1.0, &w);
        assert!((v - (4.0 + 9.0)).abs() < 1e-12);
        // paper-literal: 2*1 + (-3)*1 + 0 = -1 -> clamped? no: sums to -1 -> 0 clamp
        // actually 2 - 3 = -1 -> clamped to 0
        assert_eq!(cv.sum_variance_paper(1.0, &w), 0.0);
    }

    #[test]
    fn observe_prefix_only_touches_prefix() {
        let mut cv = ClassVariance::new(4);
        let order = [2usize, 0, 3, 1];
        let xs = [10.0, 20.0, 30.0, 40.0];
        cv.observe_prefix(1.0, &order, &xs, 2); // features 2 and 0
        assert_eq!(cv.side(1.0)[2].count(), 1);
        assert_eq!(cv.side(1.0)[0].count(), 1);
        assert_eq!(cv.side(1.0)[3].count(), 0);
        assert_eq!(cv.side(1.0)[1].count(), 0);
        assert_eq!(cv.total_observations(), 2);
    }

    #[test]
    fn table_merge_matches_sequential() {
        let mut a = ClassVariance::new(2);
        let mut b = ClassVariance::new(2);
        let mut both = ClassVariance::new(2);
        for i in 0..10 {
            let x = (i as f64).sqrt();
            a.observe(1.0, 0, x);
            both.observe(1.0, 0, x);
        }
        for i in 0..7 {
            let x = -(i as f64);
            b.observe(1.0, 0, x);
            both.observe(1.0, 0, x);
        }
        a.merge(&b);
        assert!((a.var(1.0, 0) - both.var(1.0, 0)).abs() < 1e-10);
    }
}
