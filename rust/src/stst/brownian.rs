//! Brownian-bridge boundary-crossing mathematics (paper §3.2 + Appendix).
//!
//! The paper's Lemma 1 / Lemma 2: for a random walk conditioned on its
//! endpoint (a Brownian bridge after the usual functional-CLT
//! approximation), the probability that the path touches a constant level
//! `τ > max(0, θ)` before time `n`, given it ends at `θ`, follows from the
//! reflection principle:
//!
//! ```text
//! P(T_τ < n | S_n = θ) = φ((2τ−θ)/σ) / φ(θ/σ) = exp(−2τ(τ−θ)/σ²)
//! ```
//!
//! with `σ² = var(S_n)`. All functions here are pure and deterministic;
//! they are exercised both by unit tests (closed-form identities) and by
//! the Monte-Carlo simulator in [`crate::sim`] (Figure 2a agreement).

/// Standard normal probability density function.
pub fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF via the complementary error function
/// (Abramowitz–Stegun 7.1.26 rational approximation of `erf`, |err| < 1.5e-7;
/// plenty for boundary design, and dependency-free).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Error function, Abramowitz–Stegun 7.1.26.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Lemma 1: probability that a Brownian bridge ending at `theta` with
/// total variance `var_sn` touches the constant level `tau` before `n`.
///
/// Requires `tau >= theta.max(0.0)`; for `tau` below the endpoint the
/// crossing is certain and the function saturates at 1.
pub fn bridge_crossing_prob(tau: f64, theta: f64, var_sn: f64) -> f64 {
    if var_sn <= 0.0 {
        // Degenerate bridge: the path is the straight line 0 → θ, so it
        // crosses τ iff τ lies between the endpoints.
        return if tau <= theta.max(0.0) && tau >= theta.min(0.0) { 1.0 } else { 0.0 };
    }
    if tau <= theta.max(0.0) {
        return 1.0;
    }
    (-2.0 * tau * (tau - theta) / var_sn).exp().min(1.0)
}

/// Inverse of [`bridge_crossing_prob`] in `tau`: the constant level that a
/// bridge ending at `theta` crosses with probability exactly `delta`.
///
/// Solves `exp(−2τ(τ−θ)/σ²) = δ` ⇔ `τ² − τθ − σ²·log(1/√δ) = 0`, i.e.
/// (paper eq. 8). The positive root is
///
/// ```text
/// τ = θ/2 + sqrt(θ²/4 + var·log(1/√δ))
/// ```
///
/// which for `θ = 0` reduces to the paper's simplified Constant STST
/// boundary `τ = sqrt(var)·sqrt(log(1/√δ))` (Theorem 1).
pub fn constant_boundary_level(delta: f64, theta: f64, var_sn: f64) -> f64 {
    debug_assert!(delta > 0.0 && delta < 1.0, "delta must be in (0,1), got {delta}");
    let l = log_inv_sqrt(delta);
    let half = 0.5 * theta;
    half + (half * half + var_sn.max(0.0) * l).sqrt()
}

/// The paper-literal form of eq. (10): `τ = θ + sqrt(θ²/4 + var·L)`.
///
/// The paper's algebra between eq. (8) and eq. (10) drops a factor (the
/// completed square should be `(τ − θ/2)²`); we keep this variant around
/// because Algorithm 1 and the experiments use it, and the ablation bench
/// compares both. At `θ = 0` the two coincide.
pub fn constant_boundary_level_paper(delta: f64, theta: f64, var_sn: f64) -> f64 {
    let l = log_inv_sqrt(delta);
    theta + (0.25 * theta * theta + var_sn.max(0.0) * l).sqrt()
}

/// `log(1/sqrt(delta)) = -0.5 * ln(delta)`, the "error-spending budget"
/// term that appears in every Constant-STST expression.
pub fn log_inv_sqrt(delta: f64) -> f64 {
    -0.5 * delta.ln()
}

/// Standard normal quantile function (inverse CDF), Acklam's rational
/// approximation (|relative err| < 1.15e-9 over (0,1)).
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile needs p in (0,1), got {p}");
    // Coefficients for the central and tail regions.
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Curved (curtailed) STST boundary at relative progress `frac = i/n`
/// (the conservative prior boundary the paper contrasts against, §3.1).
///
/// Derived from the curtailed conditional (paper eq. 2): given the walk
/// sits at `S_i = s`, the remaining sum is ≈ N(E[S_{in}], var(S_n)(1−i/n)),
/// so `P(S_n < θ | stop at s) ≤ δ` needs
///
/// ```text
/// τ_i = θ + z_{1−δ} · sqrt( var(S_n) · (1 − i/n) )
/// ```
///
/// (dropping the positive remaining drift E[S_{in}], which only raises the
/// boundary). The *conditional* error stays constant along the curve —
/// which is exactly why it is conservative early: at i ≈ 0 the level sits
/// z·sqrt(var(S_n)) above θ, far higher than the Constant STST's
/// error-spending level.
pub fn curved_boundary_level(delta: f64, theta: f64, var_sn: f64, frac: f64) -> f64 {
    let frac = frac.clamp(0.0, 1.0);
    let remaining_var = var_sn.max(0.0) * (1.0 - frac);
    theta + normal_quantile(1.0 - delta) * remaining_var.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn erf_reference_values() {
        // Known values of erf to the approximation's advertised accuracy.
        assert!(close(erf(0.0), 0.0, 1e-7));
        assert!(close(erf(1.0), 0.8427007929, 1e-6));
        assert!(close(erf(2.0), 0.9953222650, 1e-6));
        assert!(close(erf(-1.0), -0.8427007929, 1e-6));
    }

    #[test]
    fn normal_cdf_symmetry_and_tails() {
        assert!(close(normal_cdf(0.0), 0.5, 1e-9));
        for z in [0.5, 1.0, 1.96, 3.0] {
            assert!(close(normal_cdf(z) + normal_cdf(-z), 1.0, 1e-7));
        }
        assert!(normal_cdf(8.0) > 0.999999);
        assert!(normal_cdf(-8.0) < 1e-6);
    }

    #[test]
    fn crossing_prob_matches_reflection_ratio() {
        // exp form must equal the pdf-ratio form of the Appendix (eq. 26-28).
        let (tau, theta, var): (f64, f64, f64) = (3.0, 1.0, 4.0);
        let sigma = var.sqrt();
        let ratio = normal_pdf((2.0 * tau - theta) / sigma) / normal_pdf(theta / sigma);
        assert!(close(bridge_crossing_prob(tau, theta, var), ratio, 1e-12));
    }

    #[test]
    fn crossing_prob_saturates() {
        assert_eq!(bridge_crossing_prob(0.5, 1.0, 4.0), 1.0); // level below endpoint
        assert_eq!(bridge_crossing_prob(2.0, 0.0, 0.0), 0.0); // degenerate walk
        assert_eq!(bridge_crossing_prob(-1.0, -2.0, 0.0), 1.0);
    }

    #[test]
    fn boundary_inverts_crossing_probability() {
        for delta in [0.01, 0.05, 0.1, 0.3] {
            for theta in [0.0, 0.5, 1.0, 2.0] {
                for var in [0.5, 1.0, 10.0, 100.0] {
                    let tau = constant_boundary_level(delta, theta, var);
                    let p = bridge_crossing_prob(tau, theta, var);
                    assert!(
                        close(p, delta, 1e-9),
                        "delta={delta} theta={theta} var={var}: tau={tau} p={p}"
                    );
                }
            }
        }
    }

    #[test]
    fn simplified_theorem1_form_at_theta_zero() {
        for delta in [0.01, 0.1, 0.5] {
            for var in [1.0, 7.0, 784.0] {
                let tau = constant_boundary_level(delta, 0.0, var);
                let simplified = var.sqrt() * log_inv_sqrt(delta).sqrt();
                assert!(close(tau, simplified, 1e-12));
                // paper-literal agrees at theta = 0
                assert!(close(constant_boundary_level_paper(delta, 0.0, var), tau, 1e-12));
            }
        }
    }

    #[test]
    fn paper_literal_is_more_conservative_for_positive_theta() {
        // paper's tau = theta + sqrt(...) > correct tau = theta/2 + sqrt(...)
        let (d, v) = (0.1, 10.0);
        for theta in [0.5, 1.0, 3.0] {
            assert!(
                constant_boundary_level_paper(d, theta, v) > constant_boundary_level(d, theta, v)
            );
        }
    }

    #[test]
    fn boundary_monotonicity() {
        // tau decreases as delta grows (more error allowed => stop earlier),
        // increases with variance and with theta.
        let t1 = constant_boundary_level(0.01, 1.0, 10.0);
        let t2 = constant_boundary_level(0.2, 1.0, 10.0);
        assert!(t1 > t2);
        assert!(constant_boundary_level(0.1, 1.0, 20.0) > constant_boundary_level(0.1, 1.0, 10.0));
        assert!(constant_boundary_level(0.1, 2.0, 10.0) > constant_boundary_level(0.1, 1.0, 10.0));
    }

    #[test]
    fn curved_boundary_shape() {
        // Monotone decreasing in i: conservative early, permissive late,
        // exactly theta at the end.
        let (d, v) = (0.1, 100.0);
        let start = curved_boundary_level(d, 0.0, v, 0.0);
        let mid = curved_boundary_level(d, 0.0, v, 0.5);
        let end = curved_boundary_level(d, 0.0, v, 1.0);
        assert!(start > mid && mid > end);
        assert!(close(end, 0.0, 1e-12));
        // z_{0.9} ≈ 1.2816: start = 1.2816 * 10
        assert!(close(start, 12.8155, 1e-3));
        // And it dominates the Constant boundary early on (conservatism).
        let constant = constant_boundary_level(d, 0.0, v);
        assert!(start > constant, "curved {start} must exceed constant {constant} at i=0");
    }

    #[test]
    fn normal_quantile_reference_values() {
        assert!(close(normal_quantile(0.5), 0.0, 1e-9));
        assert!(close(normal_quantile(0.975), 1.959963985, 1e-6));
        assert!(close(normal_quantile(0.9), 1.2815515655, 1e-6));
        assert!(close(normal_quantile(0.01), -2.3263478740, 1e-6));
        // Inverse relationship with our CDF (to the CDF's accuracy).
        for p in [0.05, 0.3, 0.7, 0.95] {
            assert!(close(normal_cdf(normal_quantile(p)), p, 1e-5));
        }
    }

    #[test]
    fn log_inv_sqrt_values() {
        assert!(close(log_inv_sqrt(0.1), 0.5 * (10.0f64).ln(), 1e-12));
        assert!(close(log_inv_sqrt(1.0), 0.0, 1e-12));
    }
}
