//! Sequential Thresholded Sum Tests (STST).
//!
//! This module is the paper's core contribution. Given a weighted sum of
//! bounded random variables `S_n = Σ w_i x_i` that will eventually be
//! compared to a threshold `θ` ("is this example important enough to
//! trigger a model update?"), an STST provides *stopping boundaries*
//! `τ_i` such that evaluation can be abandoned at coordinate `i` as soon
//! as the partial sum `S_i > τ_i`, while the rate of *decision errors*
//! (`stop fired but the full sum would actually have landed below θ`)
//! stays below a user-chosen `δ`.
//!
//! The derivation (paper §3, Lemma 1) approximates the conditioned random
//! walk `(S_i | S_n = θ)` by a Brownian bridge and uses the reflection
//! principle to get the crossing probability in closed form:
//!
//! ```text
//! P(T_τ < n | S_n = θ) = exp(−2 τ (τ − θ) / var(S_n))
//! ```
//!
//! Solving `exp{·} = δ` for `τ` yields the **Constant STST** boundary —
//! flat in `i`, "error-spending": generous early, strict late.
//!
//! Submodules:
//! * [`brownian`] — bridge crossing probabilities, Gaussian helpers.
//! * [`boundary`] — the [`boundary::Boundary`] trait and all concrete
//!   boundaries (Constant, Curved, Budgeted, Trivial).
//! * [`variance`] — online per-class, per-feature variance estimation
//!   (Welford), plus `var(S_n)` aggregation under the independence
//!   assumption of paper §4.
//! * [`decision`] — decision-error bookkeeping used to *verify* that the
//!   empirical error rate honors `δ` (Figure 2a).
//! * [`wald`] — Wald's identity and expected-stopping-time estimates
//!   (Theorem 2, `E[T] = O(sqrt(n))`).

pub mod boundary;
pub mod brownian;
pub mod decision;
pub mod variance;
pub mod wald;

pub use boundary::{Boundary, BudgetedBoundary, ConstantBoundary, CurvedBoundary, TrivialBoundary};
pub use decision::DecisionAudit;
pub use variance::{ClassVariance, OnlineVariance};
