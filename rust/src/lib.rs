//! # attentive — Rapid Learning with Stochastic Focus of Attention
//!
//! A production-grade reproduction of *"Rapid Learning with Stochastic
//! Focus of Attention"* (Pelossof & Ying, ICML 2011).
//!
//! The paper's contribution is the **Sequential Thresholded Sum Test
//! (STST)**: an adaptive early-stopping rule, derived from Brownian-bridge
//! boundary-crossing probabilities, that lets a margin-based online
//! learner abandon the evaluation of an example's features as soon as the
//! partial margin makes the full-margin decision statistically obvious.
//! Plugged into Pegasos it yields **Attentive Pegasos**, which touches
//! `O(sqrt(n))` features per example on average instead of `n` with no
//! loss in accuracy.
//!
//! ## Crate layout
//!
//! | module | contents |
//! |---|---|
//! | [`stst`] | boundary family (Constant / Curved / Budgeted / Trivial), Brownian-bridge math, online variance tracking, decision-error audit |
//! | [`margin`] | sequential partial-sum walker, coordinate-selection policies, scalar & blocked margin evaluators |
//! | [`learner`] | Pegasos, Attentive Pegasos (Algorithm 1), Budgeted Pegasos, (attentive) Perceptron, (attentive) Passive-Aggressive |
//! | [`data`] | synthetic digit-glyph generator, MNIST IDX reader, 1-vs-1 task extraction, normalization, streaming, libsvm I/O |
//! | [`sim`] | random-walk simulator reproducing Figure 2 (boundary crossing + O(sqrt(n)) stopping times) |
//! | [`runtime`] | PJRT (XLA) runtime: loads AOT artifacts produced by `python/compile/aot.py` and runs them from rust (feature `pjrt`) |
//! | [`coordinator`] | online training loop, decision-error audit, multi-task parallel scheduler, async prediction service |
//! | [`server`] | network serving: JSON-lines TCP front-end with attentive early-exit, bounded-queue load shedding, hot model reload, and a load-generator client |
//! | [`metrics`] | counters, learning curves, feature-cost accounting, CSV/JSON export |
//! | [`config`] | experiment configuration and CLI plumbing |
//!
//! ## Quickstart
//!
//! ```no_run
//! use attentive::prelude::*;
//!
//! // Generate a synthetic MNIST-like 2-vs-3 task.
//! let ds = attentive::data::synth::SynthDigits::new(7).generate(2_000);
//! let task = attentive::data::task::BinaryTask::one_vs_one(&ds, 2, 3).unwrap();
//!
//! // Train Attentive Pegasos with the Constant STST boundary, delta = 0.1.
//! let cfg = attentive::learner::pegasos::PegasosConfig { lambda: 1e-4, ..Default::default() };
//! let mut learner = attentive::learner::attentive::AttentivePegasos::new(
//!     task.dim(), cfg, attentive::stst::boundary::ConstantBoundary::new(0.1));
//! let report = attentive::coordinator::trainer::Trainer::new(Default::default())
//!     .fit(&mut learner, &task);
//! println!("avg features/example: {:.1}", report.avg_features_per_example());
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod learner;
pub mod margin;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod sim;
pub mod stst;
pub mod util;

/// Convenient glob-import of the most frequently used types.
pub mod prelude {
    pub use crate::coordinator::trainer::{Trainer, TrainerConfig, TrainReport};
    pub use crate::data::dataset::{Dataset, Example};
    pub use crate::data::task::BinaryTask;
    pub use crate::error::{Error, Result};
    pub use crate::learner::attentive::AttentivePegasos;
    pub use crate::learner::pegasos::{Pegasos, PegasosConfig};
    pub use crate::learner::OnlineLearner;
    pub use crate::margin::policy::CoordinatePolicy;
    pub use crate::stst::boundary::{Boundary, ConstantBoundary, CurvedBoundary};
}
