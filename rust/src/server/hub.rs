//! [`ModelHub`]: the swappable serving core behind the TCP front-end.
//!
//! Wraps [`PredictionService`] and adds the one thing a long-running
//! server needs that the in-process service does not have: **hot model
//! reload**. A reload spawns a fresh worker generation for the new
//! [`ModelSnapshot`], atomically swaps the admission handle, and retires
//! the old generation. Retiring drops the old generation's only
//! [`ServiceHandle`], so its workers drain every request already admitted
//! to their queue — each carries its own response channel — and then
//! exit: the swap is zero-downtime and drops no request.
//!
//! Statistics are aggregated across generations, so throughput and
//! features-touched histograms survive reloads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Mutex;

use crate::coordinator::service::{
    ModelSnapshot, PredictionService, RunningService, ScoreResponse, ServiceHandle, StatsSnapshot,
    SubmitError,
};

/// Why the hub rejected a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HubError {
    /// Admission queue full — shed with an explicit `overloaded` reply.
    Overloaded,
    /// The hub has shut down.
    Closed,
    /// Feature vector length does not match the serving model.
    DimMismatch {
        /// The serving model's dimensionality.
        expected: usize,
        /// The request's dimensionality.
        got: usize,
    },
}

impl std::fmt::Display for HubError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HubError::Overloaded => write!(f, "overloaded"),
            HubError::Closed => write!(f, "service closed"),
            HubError::DimMismatch { expected, got } => {
                write!(f, "dimension mismatch: model dim {expected}, request dim {got}")
            }
        }
    }
}

struct HubState {
    /// Admission handle of the live generation (`None` after shutdown).
    handle: Option<ServiceHandle>,
    /// The live generation's workers + stats.
    current: Option<RunningService>,
    /// Older generations still draining (joined at shutdown).
    retired: Vec<RunningService>,
    /// Dimensionality of the live model.
    dim: usize,
    /// Reload generation (perturbs the policy RNG seed per generation).
    epoch: u64,
    /// Totals from generations already joined.
    closed_total: StatsSnapshot,
}

/// A prediction service with atomically swappable model generations.
pub struct ModelHub {
    inner: Mutex<HubState>,
    reloads: AtomicU64,
    max_batch: usize,
    queue: usize,
    workers: usize,
    seed: u64,
}

impl ModelHub {
    /// Spawn the first generation for `snapshot`.
    pub fn new(
        snapshot: ModelSnapshot,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
    ) -> Self {
        let dim = snapshot.weights.len();
        let (handle, run) =
            PredictionService::new(snapshot, max_batch, queue, seed).with_workers(workers).spawn();
        Self {
            inner: Mutex::new(HubState {
                handle: Some(handle),
                current: Some(run),
                retired: Vec::new(),
                dim,
                epoch: 0,
                closed_total: StatsSnapshot::default(),
            }),
            reloads: AtomicU64::new(0),
            max_batch,
            queue,
            workers,
            seed,
        }
    }

    /// Dimensionality of the model currently being served.
    pub fn dim(&self) -> usize {
        self.inner.lock().unwrap().dim
    }

    /// Hot reloads applied so far.
    pub fn reloads(&self) -> u64 {
        self.reloads.load(Ordering::Relaxed)
    }

    /// Non-blocking admission. On success the returned receiver is
    /// guaranteed to yield exactly one response: admitted requests are
    /// answered even if a reload retires their generation first.
    pub fn submit(&self, features: Vec<f64>) -> Result<Receiver<ScoreResponse>, HubError> {
        let (handle, dim) = {
            let st = self.inner.lock().unwrap();
            (st.handle.clone().ok_or(HubError::Closed)?, st.dim)
        };
        if features.len() != dim {
            return Err(HubError::DimMismatch { expected: dim, got: features.len() });
        }
        handle.submit(features).map_err(|e| match e {
            SubmitError::Overloaded => HubError::Overloaded,
            SubmitError::Closed => HubError::Closed,
        })
    }

    /// Hot-swap the serving model. Spawns the new generation outside the
    /// lock, then swaps the handle atomically; returns the new
    /// dimensionality. In-flight requests finish on the old generation.
    pub fn reload(&self, snapshot: ModelSnapshot) -> Result<usize, HubError> {
        let dim = snapshot.weights.len();
        let epoch = {
            let st = self.inner.lock().unwrap();
            if st.handle.is_none() {
                return Err(HubError::Closed);
            }
            st.epoch + 1
        };
        // Distinct policy RNG stream per generation.
        let seed = self.seed ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let (handle, run) = PredictionService::new(snapshot, self.max_batch, self.queue, seed)
            .with_workers(self.workers)
            .spawn();
        let mut st = self.inner.lock().unwrap();
        if st.handle.is_none() {
            // Shut down while we were spawning: tear the newcomer down.
            drop(st);
            drop(handle);
            run.join();
            return Err(HubError::Closed);
        }
        st.handle = Some(handle); // old handle dropped -> old workers drain & exit
        if let Some(old) = st.current.take() {
            st.retired.push(old);
        }
        st.current = Some(run);
        st.dim = dim;
        st.epoch = epoch;
        drop(st);
        self.reloads.fetch_add(1, Ordering::Relaxed);
        Ok(dim)
    }

    /// Aggregate statistics across every generation, live and retired.
    pub fn stats(&self) -> StatsSnapshot {
        let st = self.inner.lock().unwrap();
        let mut total = st.closed_total;
        for run in &st.retired {
            total.add(&run.stats.snapshot());
        }
        if let Some(run) = &st.current {
            total.add(&run.stats.snapshot());
        }
        total
    }

    /// Stop admitting, drain every generation, and join all workers.
    /// Returns the final aggregated statistics. Idempotent.
    pub fn shutdown(&self) -> StatsSnapshot {
        let (current, retired) = {
            let mut st = self.inner.lock().unwrap();
            st.handle = None;
            (st.current.take(), std::mem::take(&mut st.retired))
        };
        let mut drained = StatsSnapshot::default();
        for run in retired.into_iter().chain(current) {
            let stats = run.stats.clone();
            run.join();
            drained.add(&stats.snapshot());
        }
        let mut st = self.inner.lock().unwrap();
        st.closed_total.add(&drained);
        st.closed_total
    }
}

impl Drop for ModelHub {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn snapshot(dim: usize, w: f64) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![w; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    #[test]
    fn submit_checks_dimensions_and_answers() {
        let hub = ModelHub::new(snapshot(16, 1.0), 4, 64, 1, 0);
        assert_eq!(hub.dim(), 16);
        let rx = hub.submit(vec![1.0; 16]).unwrap();
        assert!(rx.recv().unwrap().score > 0.0);
        match hub.submit(vec![1.0; 3]) {
            Err(HubError::DimMismatch { expected: 16, got: 3 }) => {}
            other => panic!("expected dim mismatch, got {other:?}"),
        }
    }

    #[test]
    fn reload_flips_predictions_and_keeps_stats() {
        let hub = ModelHub::new(snapshot(8, 1.0), 4, 64, 1, 0);
        let x = vec![1.0; 8];
        let before = hub.submit(x.clone()).unwrap().recv().unwrap();
        assert!(before.score > 0.0);
        assert_eq!(hub.reload(snapshot(8, -1.0)).unwrap(), 8);
        assert_eq!(hub.reloads(), 1);
        let after = hub.submit(x).unwrap().recv().unwrap();
        assert!(after.score < 0.0, "reloaded model must change the prediction");
        // Stats aggregate across the generations.
        let s = hub.stats();
        assert_eq!(s.served, 2);
        let final_stats = hub.shutdown();
        assert_eq!(final_stats.served, 2);
        assert!(matches!(hub.submit(vec![0.0; 8]), Err(HubError::Closed)));
        assert!(matches!(hub.reload(snapshot(8, 1.0)), Err(HubError::Closed)));
    }

    #[test]
    fn reload_mid_flight_drops_no_admitted_request() {
        let dim = 64;
        let hub = ModelHub::new(snapshot(dim, 1.0), 4, 256, 2, 7);
        // Admit a burst, swap generations immediately, then collect.
        let pending: Vec<_> =
            (0..100).map(|_| hub.submit(vec![1.0; dim]).unwrap()).collect();
        hub.reload(snapshot(dim, -1.0)).unwrap();
        for rx in pending {
            let resp = rx.recv().expect("admitted before the swap => answered");
            assert!(!resp.score.is_nan());
        }
        // And the new generation serves too.
        let resp = hub.submit(vec![1.0; dim]).unwrap().recv().unwrap();
        assert!(resp.score < 0.0);
        assert_eq!(hub.stats().served, 101);
    }

    #[test]
    fn reload_can_change_dimensionality() {
        let hub = ModelHub::new(snapshot(8, 1.0), 4, 64, 1, 0);
        assert_eq!(hub.reload(snapshot(32, 0.5)).unwrap(), 32);
        assert_eq!(hub.dim(), 32);
        assert!(matches!(
            hub.submit(vec![1.0; 8]),
            Err(HubError::DimMismatch { expected: 32, got: 8 })
        ));
        assert!(hub.submit(vec![1.0; 32]).is_ok());
    }
}
