//! [`ModelHub`]: the swappable serving core behind the TCP front-end.
//!
//! Wraps [`PredictionService`] and adds the one thing a long-running
//! server needs that the in-process service does not have: **hot model
//! reload**. A reload spawns a fresh worker generation for the new
//! [`ServingModel`], atomically swaps the admission handle, and retires
//! the old generation. Retiring drops the old generation's only
//! [`ServiceHandle`], so its workers drain every request already admitted
//! to their queue — each carries its own response channel — and then
//! exit: the swap is zero-downtime and drops no request.
//!
//! Statistics are aggregated across generations, so throughput and
//! features-touched histograms survive reloads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};

use crate::config::BrownoutConfig;
use crate::coordinator::service::{
    CompletionNotifier, Features, PredictionService, ReqKind, RunningService, ScoreResponse,
    ServiceHandle, ServingModel, StatsSnapshot, SubmitError, SubmitOpts,
};

/// Why the hub rejected a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HubError {
    /// Admission queue full — shed with an explicit `overloaded` reply.
    Overloaded,
    /// The hub has shut down.
    Closed,
    /// Feature vector length does not match the serving model.
    DimMismatch {
        /// The serving model's dimensionality.
        expected: usize,
        /// The request's dimensionality.
        got: usize,
    },
    /// The request pinned a model generation that is no longer serving.
    StaleGeneration {
        /// The generation the request asked for.
        requested: u32,
        /// The generation actually serving.
        serving: u32,
    },
    /// The op does not match the shard's model kind (`score` needs a
    /// binary model, `classify` an ensemble).
    WrongKind {
        /// The op that was requested.
        op: &'static str,
        /// The kind of model the shard serves.
        serving: &'static str,
    },
}

impl std::fmt::Display for HubError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HubError::Overloaded => write!(f, "overloaded"),
            HubError::Closed => write!(f, "service closed"),
            HubError::DimMismatch { expected, got } => {
                write!(f, "dimension mismatch: model dim {expected}, request dim {got}")
            }
            HubError::StaleGeneration { requested, serving } => {
                write!(f, "stale generation: requested {requested}, serving {serving}")
            }
            HubError::WrongKind { op, serving } => {
                let needed = match *op {
                    "classify" => "an ensemble",
                    _ => "a binary",
                };
                write!(f, "wrong model kind: op {op} needs {needed} model, shard serves {serving}")
            }
        }
    }
}

/// One consistent observation of a hub's serving state (taken in a
/// single critical section, so none of the fields tear across a
/// concurrent reload).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HubInfo {
    /// Serving model generation (1-based; bumped by every reload).
    pub gen: u32,
    /// Feature dimensionality.
    pub dim: usize,
    /// `"binary"` or `"ensemble"`.
    pub kind: &'static str,
    /// Voters behind the shard (0 for binary).
    pub voters: usize,
}

struct HubState {
    /// Admission handle of the live generation (`None` after shutdown).
    handle: Option<ServiceHandle>,
    /// The live generation's workers + stats.
    current: Option<RunningService>,
    /// Older generations still draining (joined at shutdown).
    retired: Vec<RunningService>,
    /// Dimensionality of the live model.
    dim: usize,
    /// Request kind the live model answers (score / classify).
    accepts: ReqKind,
    /// Kind name of the live model (`"binary"` / `"ensemble"`).
    kind: &'static str,
    /// Voters behind the live model (0 for binary).
    voters: usize,
    /// The live model itself, retained so late trainer attachment can
    /// warm-start from whatever the shard currently serves.
    model: Arc<ServingModel>,
    /// Serving generation minus one: bumped under the same critical
    /// section as the handle swap, so each installed model gets a
    /// unique, monotonic generation even when reloads race.
    epoch: u64,
    /// Totals from generations already joined.
    closed_total: StatsSnapshot,
}

/// A prediction service with atomically swappable model generations.
pub struct ModelHub {
    inner: Mutex<HubState>,
    reloads: AtomicU64,
    /// Spawn counter salting each worker generation's policy RNG stream
    /// (independent of `epoch`: spawns that lose a shutdown race still
    /// consume a salt, which is harmless).
    spawns: AtomicU64,
    max_batch: usize,
    queue: usize,
    workers: usize,
    seed: u64,
    /// Fired by every generation's workers after each response send;
    /// survives reloads (applied to every spawned generation).
    notifier: CompletionNotifier,
    /// Overload-brownout config, applied to the first generation and to
    /// every generation a reload spawns (the controller and the tiered
    /// threshold tables are per-generation state).
    brownout: Option<BrownoutConfig>,
}

impl ModelHub {
    /// Spawn the first generation for `model` (a binary
    /// [`crate::coordinator::service::ModelSnapshot`] converts
    /// implicitly).
    pub fn new(
        model: impl Into<ServingModel>,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
    ) -> Self {
        Self::new_with_notifier(model, max_batch, queue, workers, seed, CompletionNotifier::default())
    }

    /// [`Self::new`] with a worker-completion notifier, installed on the
    /// first generation and on every generation a reload spawns.
    pub fn new_with_notifier(
        model: impl Into<ServingModel>,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
        notifier: CompletionNotifier,
    ) -> Self {
        Self::new_with_opts(model, max_batch, queue, workers, seed, notifier, None)
    }

    /// [`Self::new_with_notifier`] plus the overload-brownout config;
    /// like the notifier, it survives reloads — every spawned generation
    /// gets its own controller and tiered tables.
    pub fn new_with_opts(
        model: impl Into<ServingModel>,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
        notifier: CompletionNotifier,
        brownout: Option<BrownoutConfig>,
    ) -> Self {
        let model = Arc::new(model.into());
        let (dim, accepts, kind, voters) =
            (model.dim(), model.kind(), model.kind_name(), model.voter_count());
        let (handle, run) = PredictionService::new((*model).clone(), max_batch, queue, seed)
            .with_workers(workers)
            .with_notifier(notifier.clone())
            .with_brownout(brownout.clone())
            .spawn();
        Self {
            inner: Mutex::new(HubState {
                handle: Some(handle),
                current: Some(run),
                retired: Vec::new(),
                dim,
                accepts,
                kind,
                voters,
                model,
                epoch: 0,
                closed_total: StatsSnapshot::default(),
            }),
            reloads: AtomicU64::new(0),
            spawns: AtomicU64::new(0),
            max_batch,
            queue,
            workers,
            seed,
            notifier,
            brownout,
        }
    }

    /// Dimensionality of the model currently being served.
    pub fn dim(&self) -> usize {
        self.inner.lock().unwrap().dim
    }

    /// Hot reloads applied so far.
    pub fn reloads(&self) -> u64 {
        self.reloads.load(Ordering::Relaxed)
    }

    /// Serving model generation, starting at 1 and bumped by every hot
    /// reload. Exposed on the wire (protocol v2 `hello` and score
    /// frames) so clients can pin a generation — 0 is reserved there
    /// for "any generation".
    pub fn generation(&self) -> u32 {
        (self.inner.lock().unwrap().epoch as u32).wrapping_add(1)
    }

    /// Generation and dimensionality of the serving model, read in one
    /// critical section — the `hello` handshake advertises these as
    /// one snapshot, so they must not tear across a concurrent reload.
    pub fn serving_info(&self) -> (u32, usize) {
        let st = self.inner.lock().unwrap();
        ((st.epoch as u32).wrapping_add(1), st.dim)
    }

    /// Full serving-state observation (generation, dim, model kind,
    /// voters), taken in one critical section — the registry's `models`
    /// listing must not tear across a reload either.
    pub fn info(&self) -> HubInfo {
        let st = self.inner.lock().unwrap();
        HubInfo {
            gen: (st.epoch as u32).wrapping_add(1),
            dim: st.dim,
            kind: st.kind,
            voters: st.voters,
        }
    }

    /// Non-blocking admission of a dense or sparse payload for a binary
    /// `score`. On success the returned receiver is guaranteed to yield
    /// exactly one response: admitted requests are answered even if a
    /// reload retires their generation first. Structural validity
    /// (sorted indices, finite values) is the wire parsers' job; the hub
    /// screens dimensions and model kind only.
    pub fn submit(
        &self,
        features: impl Into<Features>,
    ) -> Result<Receiver<ScoreResponse>, HubError> {
        self.submit_pinned(features, 0, ReqKind::Score).map(|(rx, _)| rx)
    }

    /// Non-blocking admission of a `classify` request (all-pairs vote;
    /// the shard must serve an ensemble).
    pub fn submit_classify(
        &self,
        features: impl Into<Features>,
    ) -> Result<Receiver<ScoreResponse>, HubError> {
        self.submit_pinned(features, 0, ReqKind::Classify).map(|(rx, _)| rx)
    }

    /// [`Self::submit`] with protocol-v2 generation pinning and an
    /// explicit op kind: `pin` = 0 admits on any generation; a nonzero
    /// `pin` is rejected with [`HubError::StaleGeneration`] unless it
    /// matches the serving generation, and an op that does not match
    /// the serving model's kind is rejected with
    /// [`HubError::WrongKind`]. The handle, generation, and kind are
    /// captured in one critical section, so the returned generation is
    /// the one whose workers answer the request — even if a reload
    /// lands before the request reaches their queue, a retired
    /// generation drains what it admitted.
    pub fn submit_pinned(
        &self,
        features: impl Into<Features>,
        pin: u32,
        kind: ReqKind,
    ) -> Result<(Receiver<ScoreResponse>, u32), HubError> {
        self.submit_pinned_opts(features, pin, kind, SubmitOpts::default())
    }

    /// [`Self::submit_pinned`] with per-request admission options: an
    /// absolute deadline (checked at dequeue — expired work answers the
    /// retryable `DEADLINE_EXCEEDED` instead of being scored) and/or a
    /// lane override (singles default to the interactive lane).
    pub fn submit_pinned_opts(
        &self,
        features: impl Into<Features>,
        pin: u32,
        kind: ReqKind,
        opts: SubmitOpts,
    ) -> Result<(Receiver<ScoreResponse>, u32), HubError> {
        let features = features.into();
        let (handle, dim, gen, accepts, serving_kind) = {
            let st = self.inner.lock().unwrap();
            (
                st.handle.clone().ok_or(HubError::Closed)?,
                st.dim,
                (st.epoch as u32).wrapping_add(1),
                st.accepts,
                st.kind,
            )
        };
        // Verbose classify admits wherever classify does.
        if kind.base() != accepts {
            return Err(HubError::WrongKind { op: kind.name(), serving: serving_kind });
        }
        if pin != 0 && pin != gen {
            return Err(HubError::StaleGeneration { requested: pin, serving: gen });
        }
        if let Err((expected, got)) = features.check_dim(dim) {
            return Err(HubError::DimMismatch { expected, got });
        }
        handle.submit_opts(features, kind, opts).map(|rx| (rx, gen)).map_err(|e| match e {
            SubmitError::Overloaded => HubError::Overloaded,
            SubmitError::Closed => HubError::Closed,
        })
    }

    /// Non-blocking admission of a whole score batch as **one queue
    /// unit** (protocol v6 `SCORE_BATCH`). Whole-batch screens — model
    /// kind, generation pin, queue room — apply once, exactly as for a
    /// single request; per-example dimensionality is deliberately *not*
    /// screened here: a bad example rejects alone in its response slot
    /// (the worker's NaN sentinel, rendered as a per-example status on
    /// the wire) and cannot poison the rest of the batch. On success
    /// the receiver yields one response per example in submission
    /// order, and the returned generation is the one whose workers
    /// answer — captured in the same critical section as the handle.
    pub fn submit_batch(
        &self,
        examples: Vec<Features>,
        pin: u32,
    ) -> Result<(Receiver<Vec<ScoreResponse>>, u32), HubError> {
        self.submit_batch_opts(examples, pin, SubmitOpts::default())
    }

    /// [`Self::submit_batch`] with per-request admission options: one
    /// deadline covering the whole batch (an expired batch answers
    /// `DEADLINE_EXCEEDED` in every slot) and/or a lane override
    /// (batches default to the bulk lane, which brownout tier 3 sheds).
    pub fn submit_batch_opts(
        &self,
        examples: Vec<Features>,
        pin: u32,
        opts: SubmitOpts,
    ) -> Result<(Receiver<Vec<ScoreResponse>>, u32), HubError> {
        let (handle, gen, accepts, serving_kind) = {
            let st = self.inner.lock().unwrap();
            (
                st.handle.clone().ok_or(HubError::Closed)?,
                (st.epoch as u32).wrapping_add(1),
                st.accepts,
                st.kind,
            )
        };
        if accepts != ReqKind::Score {
            return Err(HubError::WrongKind { op: "score", serving: serving_kind });
        }
        if pin != 0 && pin != gen {
            return Err(HubError::StaleGeneration { requested: pin, serving: gen });
        }
        handle.submit_batch_opts(examples, opts).map(|rx| (rx, gen)).map_err(|e| match e {
            SubmitError::Overloaded => HubError::Overloaded,
            SubmitError::Closed => HubError::Closed,
        })
    }

    /// Hot-swap the serving model (the kind may change along with the
    /// dimensionality). Spawns the new generation outside the lock,
    /// then swaps the handle atomically; returns the new
    /// dimensionality. In-flight requests finish on the old generation.
    /// The generation number is bumped inside the swap's critical
    /// section, so concurrent reloads each install a distinct,
    /// monotonic generation (any connection can be a control channel).
    pub fn reload(&self, model: impl Into<ServingModel>) -> Result<usize, HubError> {
        let model = Arc::new(model.into());
        let (dim, accepts, kind, voters) =
            (model.dim(), model.kind(), model.kind_name(), model.voter_count());
        if self.inner.lock().unwrap().handle.is_none() {
            return Err(HubError::Closed);
        }
        // Distinct policy RNG stream per spawned generation; its own
        // counter, so racing reloads never share a stream.
        let salt = self.spawns.fetch_add(1, Ordering::Relaxed) + 1;
        let seed = self.seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let (handle, run) =
            PredictionService::new((*model).clone(), self.max_batch, self.queue, seed)
                .with_workers(self.workers)
                .with_notifier(self.notifier.clone())
                .with_brownout(self.brownout.clone())
                .spawn();
        let mut st = self.inner.lock().unwrap();
        if st.handle.is_none() {
            // Shut down while we were spawning: tear the newcomer down.
            drop(st);
            drop(handle);
            run.join();
            return Err(HubError::Closed);
        }
        st.handle = Some(handle); // old handle dropped -> old workers drain & exit
        if let Some(old) = st.current.take() {
            st.retired.push(old);
        }
        st.current = Some(run);
        st.dim = dim;
        st.accepts = accepts;
        st.kind = kind;
        st.voters = voters;
        st.model = model;
        st.epoch += 1;
        drop(st);
        self.reloads.fetch_add(1, Ordering::Relaxed);
        Ok(dim)
    }

    /// The model currently being served (the last one installed by
    /// construction or [`Self::reload`]). Cheap: an `Arc` refcount bump
    /// under the state lock. Used to warm-start a trainer attached to a
    /// shard that already carries trained weights.
    pub fn serving_model(&self) -> Arc<ServingModel> {
        Arc::clone(&self.inner.lock().unwrap().model)
    }

    /// Admission-queue occupancy and capacity of the live generation
    /// (see [`ServiceHandle::queue_load`]); `(0, capacity)` after
    /// shutdown. The front-end derives the adaptive `SCORE_BATCH`
    /// admission cap from this.
    pub fn queue_load(&self) -> (usize, usize) {
        let st = self.inner.lock().unwrap();
        match &st.handle {
            Some(h) => h.queue_load(),
            None => (0, self.queue),
        }
    }

    /// Aggregate statistics across every generation, live and retired.
    pub fn stats(&self) -> StatsSnapshot {
        let st = self.inner.lock().unwrap();
        let mut total = st.closed_total;
        for run in &st.retired {
            total.add(&run.stats.snapshot());
        }
        if let Some(run) = &st.current {
            total.add(&run.stats.snapshot());
        }
        total
    }

    /// Stop admitting, drain every generation, and join all workers.
    /// Returns the final aggregated statistics. Idempotent.
    pub fn shutdown(&self) -> StatsSnapshot {
        let (current, retired) = {
            let mut st = self.inner.lock().unwrap();
            st.handle = None;
            (st.current.take(), std::mem::take(&mut st.retired))
        };
        let mut drained = StatsSnapshot::default();
        for run in retired.into_iter().chain(current) {
            let stats = run.stats.clone();
            run.join();
            drained.add(&stats.snapshot());
        }
        let mut st = self.inner.lock().unwrap();
        st.closed_total.add(&drained);
        st.closed_total
    }
}

impl Drop for ModelHub {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{EnsembleSnapshot, ModelSnapshot, VoterSnapshot};
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn snapshot(dim: usize, w: f64) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![w; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    #[test]
    fn submit_checks_dimensions_and_answers() {
        let hub = ModelHub::new(snapshot(16, 1.0), 4, 64, 1, 0);
        assert_eq!(hub.dim(), 16);
        let rx = hub.submit(vec![1.0; 16]).unwrap();
        assert!(rx.recv().unwrap().score > 0.0);
        match hub.submit(vec![1.0; 3]) {
            Err(HubError::DimMismatch { expected: 16, got: 3 }) => {}
            other => panic!("expected dim mismatch, got {other:?}"),
        }
    }

    #[test]
    fn reload_flips_predictions_and_keeps_stats() {
        let hub = ModelHub::new(snapshot(8, 1.0), 4, 64, 1, 0);
        let x = vec![1.0; 8];
        let before = hub.submit(x.clone()).unwrap().recv().unwrap();
        assert!(before.score > 0.0);
        assert_eq!(hub.reload(snapshot(8, -1.0)).unwrap(), 8);
        assert_eq!(hub.reloads(), 1);
        let after = hub.submit(x).unwrap().recv().unwrap();
        assert!(after.score < 0.0, "reloaded model must change the prediction");
        // Stats aggregate across the generations.
        let s = hub.stats();
        assert_eq!(s.served, 2);
        let final_stats = hub.shutdown();
        assert_eq!(final_stats.served, 2);
        assert!(matches!(hub.submit(vec![0.0; 8]), Err(HubError::Closed)));
        assert!(matches!(hub.reload(snapshot(8, 1.0)), Err(HubError::Closed)));
    }

    #[test]
    fn reload_mid_flight_drops_no_admitted_request() {
        let dim = 64;
        let hub = ModelHub::new(snapshot(dim, 1.0), 4, 256, 2, 7);
        // Admit a burst, swap generations immediately, then collect.
        let pending: Vec<_> =
            (0..100).map(|_| hub.submit(vec![1.0; dim]).unwrap()).collect();
        hub.reload(snapshot(dim, -1.0)).unwrap();
        for rx in pending {
            let resp = rx.recv().expect("admitted before the swap => answered");
            assert!(!resp.score.is_nan());
        }
        // And the new generation serves too.
        let resp = hub.submit(vec![1.0; dim]).unwrap().recv().unwrap();
        assert!(resp.score < 0.0);
        assert_eq!(hub.stats().served, 101);
    }

    #[test]
    fn sparse_submissions_screen_dimensions_and_answer() {
        let hub = ModelHub::new(snapshot(16, 1.0), 4, 64, 1, 0);
        assert_eq!(hub.generation(), 1);
        let rx = hub
            .submit(Features::Sparse { idx: vec![0, 7, 15], val: vec![1.0, 1.0, 1.0] })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert!(resp.score > 0.0);
        assert!(resp.features_evaluated <= 3);
        match hub.submit(Features::Sparse { idx: vec![16], val: vec![1.0] }) {
            Err(HubError::DimMismatch { expected: 16, got: 17 }) => {}
            other => panic!("expected dim mismatch, got {other:?}"),
        }
        hub.reload(snapshot(16, -1.0)).unwrap();
        assert_eq!(hub.generation(), 2);
    }

    #[test]
    fn pinned_submissions_track_generations() {
        let hub = ModelHub::new(snapshot(8, 1.0), 4, 64, 1, 0);
        // Pin 0 = any; the returned generation is the serving one.
        let (rx, gen) = hub.submit_pinned(vec![1.0; 8], 0, ReqKind::Score).unwrap();
        assert_eq!(gen, 1);
        assert!(rx.recv().unwrap().score > 0.0);
        // Matching pin admits; mismatched pin sheds with both numbers.
        assert!(hub.submit_pinned(vec![1.0; 8], 1, ReqKind::Score).is_ok());
        match hub.submit_pinned(vec![1.0; 8], 9, ReqKind::Score) {
            Err(HubError::StaleGeneration { requested: 9, serving: 1 }) => {}
            other => panic!("expected stale generation, got {other:?}"),
        }
        hub.reload(snapshot(8, -1.0)).unwrap();
        match hub.submit_pinned(vec![1.0; 8], 1, ReqKind::Score) {
            Err(HubError::StaleGeneration { requested: 1, serving: 2 }) => {}
            other => panic!("expected stale generation after reload, got {other:?}"),
        }
        let (rx, gen) = hub.submit_pinned(vec![1.0; 8], 2, ReqKind::Score).unwrap();
        assert_eq!(gen, 2);
        assert!(rx.recv().unwrap().score < 0.0, "pinned to the reloaded model");
    }

    /// Flat 3-class ensemble (see the service-layer tests): positive
    /// inputs classify as 0, negative as 2, deterministically.
    fn ensemble(dim: usize) -> EnsembleSnapshot {
        let classes = vec![0i64, 1, 2];
        let mut voters = Vec::new();
        for a in 0..classes.len() {
            for b in a + 1..classes.len() {
                voters.push(VoterSnapshot {
                    pos: classes[a],
                    neg: classes[b],
                    weights: vec![1.0; dim],
                    var_sn: 4.0,
                });
            }
        }
        EnsembleSnapshot {
            classes,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
            voters,
        }
    }

    #[test]
    fn kind_screen_rejects_mismatched_ops_and_reload_can_change_kind() {
        let hub = ModelHub::new(snapshot(8, 1.0), 4, 64, 1, 0);
        assert_eq!(hub.info().kind, "binary");
        assert_eq!(hub.info().voters, 0);
        match hub.submit_classify(vec![1.0; 8]) {
            Err(HubError::WrongKind { op: "classify", serving: "binary" }) => {}
            other => panic!("expected wrong-kind, got {other:?}"),
        }
        // Swap the shard to an ensemble: classify works, score sheds.
        hub.reload(ensemble(8)).unwrap();
        let info = hub.info();
        assert_eq!((info.kind, info.voters, info.gen), ("ensemble", 3, 2));
        let resp = hub.submit_classify(vec![1.0; 8]).unwrap().recv().unwrap();
        assert_eq!(resp.classify.unwrap().label, 0);
        match hub.submit(vec![1.0; 8]) {
            Err(HubError::WrongKind { op: "score", serving: "ensemble" }) => {}
            other => panic!("expected wrong-kind, got {other:?}"),
        }
        // Generation pinning applies to classify admissions too.
        let (rx, gen) = hub.submit_pinned(vec![-1.0; 8], 2, ReqKind::Classify).unwrap();
        assert_eq!(gen, 2);
        assert_eq!(rx.recv().unwrap().classify.unwrap().label, 2);
        match hub.submit_pinned(vec![1.0; 8], 1, ReqKind::Classify) {
            Err(HubError::StaleGeneration { requested: 1, serving: 2 }) => {}
            other => panic!("expected stale generation, got {other:?}"),
        }
    }

    #[test]
    fn batch_admission_screens_whole_batch_but_rejects_per_example() {
        let hub = ModelHub::new(snapshot(8, 1.0), 4, 64, 1, 0);
        // Kind screen is whole-batch: a batch against an ensemble sheds
        // before admission, same as a single score.
        let ens_hub = ModelHub::new(ensemble(8), 4, 64, 1, 0);
        match ens_hub.submit_batch(vec![Features::Dense(vec![1.0; 8])], 0) {
            Err(HubError::WrongKind { op: "score", serving: "ensemble" }) => {}
            other => panic!("expected wrong-kind, got {other:?}"),
        }
        // Pin screen is whole-batch too.
        match hub.submit_batch(vec![Features::Dense(vec![1.0; 8])], 9) {
            Err(HubError::StaleGeneration { requested: 9, serving: 1 }) => {}
            other => panic!("expected stale generation, got {other:?}"),
        }
        // Dimensionality is per-example: the bad example rejects in its
        // slot, the rest of the batch is answered normally.
        let (rx, gen) = hub
            .submit_batch(
                vec![
                    Features::Dense(vec![1.0; 8]),
                    Features::Dense(vec![1.0; 3]),
                    Features::Dense(vec![-1.0; 8]),
                ],
                1,
            )
            .unwrap();
        assert_eq!(gen, 1);
        let out = rx.recv().unwrap();
        assert_eq!(out.len(), 3);
        assert!(out[0].score > 0.0);
        assert!(out[1].score.is_nan());
        assert!(out[2].score < 0.0);
        assert_eq!(hub.stats().served, 3, "each batch example counts as served");
    }

    #[test]
    fn serving_model_tracks_reloads() {
        let hub = ModelHub::new(snapshot(8, 1.0), 4, 64, 1, 0);
        match &*hub.serving_model() {
            ServingModel::Binary(s) => assert_eq!(s.weights, vec![1.0; 8]),
            other => panic!("expected binary serving model, got {}", other.kind_name()),
        }
        hub.reload(snapshot(8, -2.5)).unwrap();
        match &*hub.serving_model() {
            ServingModel::Binary(s) => assert_eq!(s.weights, vec![-2.5; 8]),
            other => panic!("expected binary serving model, got {}", other.kind_name()),
        }
        hub.reload(ensemble(8)).unwrap();
        assert_eq!(hub.serving_model().kind_name(), "ensemble");
    }

    #[test]
    fn reload_can_change_dimensionality() {
        let hub = ModelHub::new(snapshot(8, 1.0), 4, 64, 1, 0);
        assert_eq!(hub.reload(snapshot(32, 0.5)).unwrap(), 32);
        assert_eq!(hub.dim(), 32);
        assert!(matches!(
            hub.submit(vec![1.0; 8]),
            Err(HubError::DimMismatch { expected: 32, got: 8 })
        ));
        assert!(hub.submit(vec![1.0; 32]).is_ok());
    }
}
