//! JSON-lines wire protocol for the serving front-end.
//!
//! One compact JSON document per `\n`-terminated line, in both
//! directions. Std-only and deliberately boring: debuggable with `nc`,
//! parseable by any language, and friendly to line-oriented tooling.
//!
//! Requests:
//!
//! ```text
//! {"op":"score","features":[0.0,0.5,...],"id":7}   // id optional
//! {"op":"stats"}
//! {"op":"reload","snapshot":{...ModelSnapshot...}}
//! {"op":"ping"}
//! ```
//!
//! Responses always carry `"ok"`; errors carry `"error"` plus
//! `"retryable"` (`true` for `overloaded` shed responses, which the
//! client may retry after backing off):
//!
//! ```text
//! {"ok":true,"op":"score","id":7,"score":1.25,"features_evaluated":34}
//! {"ok":true,"op":"stats", ...StatsReport...}
//! {"ok":true,"op":"reload","dim":784}
//! {"ok":true,"op":"pong"}
//! {"ok":false,"error":"overloaded","retryable":true}
//! ```
//!
//! Responses on one connection are emitted in request order, so clients
//! can pipeline without correlating ids (ids are still echoed for
//! clients that want them).

use crate::coordinator::service::ModelSnapshot;
use crate::util::json::Json;

/// A client → server message.
#[derive(Debug, Clone)]
pub enum Request {
    /// Score one feature vector.
    Score {
        /// Optional client-chosen correlation id, echoed in the response.
        id: Option<u64>,
        /// Dense feature vector (must match the serving model's dim).
        features: Vec<f64>,
    },
    /// Fetch the server's live statistics.
    Stats,
    /// Hot-swap the serving model.
    Reload {
        /// The replacement model.
        snapshot: ModelSnapshot,
    },
    /// Liveness probe.
    Ping,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request, String> {
        let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        let op = v.get("op").and_then(|o| o.as_str()).ok_or("missing op")?;
        match op {
            "score" => {
                let id = v.get("id").and_then(|x| x.as_u64());
                let features = v
                    .get("features")
                    .and_then(|a| a.as_arr())
                    .ok_or("score: missing features")?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| "score: non-numeric feature".to_string()))
                    .collect::<Result<Vec<_>, _>>()?;
                // Reject inf/NaN here: a non-finite margin could not be
                // serialized back as valid JSON.
                if !features.iter().all(|f| f.is_finite()) {
                    return Err("score: non-finite feature".into());
                }
                Ok(Request::Score { id, features })
            }
            "stats" => Ok(Request::Stats),
            "reload" => Ok(Request::Reload {
                snapshot: ModelSnapshot::from_json(
                    v.get("snapshot").ok_or("reload: missing snapshot")?,
                )?,
            }),
            "ping" => Ok(Request::Ping),
            other => Err(format!("unknown op {other:?}")),
        }
    }

    /// Serialize (client side).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Score { id, features } => {
                let mut pairs = vec![
                    ("op", Json::Str("score".into())),
                    ("features", Json::Arr(features.iter().map(|&f| Json::Num(f)).collect())),
                ];
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Request::Stats => Json::obj([("op", Json::Str("stats".into()))]),
            Request::Reload { snapshot } => Json::obj([
                ("op", Json::Str("reload".into())),
                ("snapshot", snapshot.to_json()),
            ]),
            Request::Ping => Json::obj([("op", Json::Str("ping".into()))]),
        }
    }

    /// One wire line (compact JSON + newline).
    pub fn to_line(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }
}

/// Server statistics exposed by the `stats` op.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StatsReport {
    /// Requests scored.
    pub served: u64,
    /// Mean features touched per scored request.
    pub avg_features: f64,
    /// Fraction of scored requests that exited early.
    pub early_exit_rate: f64,
    /// Worker batches drained.
    pub batches: u64,
    /// Approx. features-touched percentiles (histogram upper edges).
    pub features_p50: u64,
    /// 90th percentile.
    pub features_p90: u64,
    /// 99th percentile.
    pub features_p99: u64,
    /// Connections accepted since start.
    pub accepted_conns: u64,
    /// Requests shed with an `overloaded` response.
    pub overloaded: u64,
    /// Lines that failed to parse as a request.
    pub protocol_errors: u64,
    /// Hot model reloads applied.
    pub reloads: u64,
    /// Seconds since the server started.
    pub uptime_s: f64,
    /// Scored requests per second over the whole uptime.
    pub req_per_s: f64,
}

impl StatsReport {
    /// Serialize the payload fields (caller adds the envelope).
    fn payload(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("served", Json::Num(self.served as f64)),
            ("avg_features", Json::Num(self.avg_features)),
            ("early_exit_rate", Json::Num(self.early_exit_rate)),
            ("batches", Json::Num(self.batches as f64)),
            ("features_p50", Json::Num(self.features_p50 as f64)),
            ("features_p90", Json::Num(self.features_p90 as f64)),
            ("features_p99", Json::Num(self.features_p99 as f64)),
            ("accepted_conns", Json::Num(self.accepted_conns as f64)),
            ("overloaded", Json::Num(self.overloaded as f64)),
            ("protocol_errors", Json::Num(self.protocol_errors as f64)),
            ("reloads", Json::Num(self.reloads as f64)),
            ("uptime_s", Json::Num(self.uptime_s)),
            ("req_per_s", Json::Num(self.req_per_s)),
        ]
    }

    /// Parse the payload fields (missing fields default to zero, so the
    /// report stays forward-compatible when the server grows counters).
    pub fn from_json(v: &Json) -> StatsReport {
        let num = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
        let int = |k: &str| v.get(k).and_then(|x| x.as_u64()).unwrap_or(0);
        StatsReport {
            served: int("served"),
            avg_features: num("avg_features"),
            early_exit_rate: num("early_exit_rate"),
            batches: int("batches"),
            features_p50: int("features_p50"),
            features_p90: int("features_p90"),
            features_p99: int("features_p99"),
            accepted_conns: int("accepted_conns"),
            overloaded: int("overloaded"),
            protocol_errors: int("protocol_errors"),
            reloads: int("reloads"),
            uptime_s: num("uptime_s"),
            req_per_s: num("req_per_s"),
        }
    }
}

/// A server → client message.
#[derive(Debug, Clone)]
pub enum Response {
    /// A scored request.
    Score {
        /// Echo of the request id, if one was sent.
        id: Option<u64>,
        /// Signed margin estimate; the prediction is its sign.
        score: f64,
        /// Features evaluated before the early exit.
        features_evaluated: usize,
    },
    /// Live statistics.
    Stats(StatsReport),
    /// A hot reload was applied; `dim` is the new model's dimensionality.
    Reloaded {
        /// New feature dimensionality.
        dim: usize,
    },
    /// Liveness answer.
    Pong,
    /// The request failed. `retryable` marks shed load (`overloaded`).
    Error {
        /// Echo of the request id, if known.
        id: Option<u64>,
        /// What went wrong.
        error: String,
        /// Whether retrying later can succeed (backpressure shed).
        retryable: bool,
    },
}

impl Response {
    /// Serialize (server side).
    pub fn to_json(&self) -> Json {
        match self {
            Response::Score { id, score, features_evaluated } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("op", Json::Str("score".into())),
                    ("score", Json::Num(*score)),
                    ("features_evaluated", Json::Num(*features_evaluated as f64)),
                ];
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Response::Stats(report) => {
                let mut pairs =
                    vec![("ok", Json::Bool(true)), ("op", Json::Str("stats".into()))];
                pairs.extend(report.payload());
                Json::obj(pairs)
            }
            Response::Reloaded { dim } => Json::obj([
                ("ok", Json::Bool(true)),
                ("op", Json::Str("reload".into())),
                ("dim", Json::Num(*dim as f64)),
            ]),
            Response::Pong => {
                Json::obj([("ok", Json::Bool(true)), ("op", Json::Str("pong".into()))])
            }
            Response::Error { id, error, retryable } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(error.clone())),
                    ("retryable", Json::Bool(*retryable)),
                ];
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
        }
    }

    /// One wire line (compact JSON + newline).
    pub fn to_line(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }

    /// Parse one response line (client side).
    pub fn parse(line: &str) -> Result<Response, String> {
        let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        let ok = v.get("ok").and_then(|b| b.as_bool()).ok_or("missing ok")?;
        if !ok {
            return Ok(Response::Error {
                id: v.get("id").and_then(|x| x.as_u64()),
                error: v
                    .get("error")
                    .and_then(|s| s.as_str())
                    .unwrap_or("unknown error")
                    .to_string(),
                retryable: v.get("retryable").and_then(|b| b.as_bool()).unwrap_or(false),
            });
        }
        match v.get("op").and_then(|o| o.as_str()).ok_or("missing op")? {
            "score" => Ok(Response::Score {
                id: v.get("id").and_then(|x| x.as_u64()),
                score: v.get("score").and_then(|x| x.as_f64()).ok_or("score: missing score")?,
                features_evaluated: v
                    .get("features_evaluated")
                    .and_then(|x| x.as_usize())
                    .ok_or("score: missing features_evaluated")?,
            }),
            "stats" => Ok(Response::Stats(StatsReport::from_json(&v))),
            "reload" => Ok(Response::Reloaded {
                dim: v.get("dim").and_then(|x| x.as_usize()).ok_or("reload: missing dim")?,
            }),
            "pong" => Ok(Response::Pong),
            other => Err(format!("unknown response op {other:?}")),
        }
    }

    /// Is this the `overloaded` shed response?
    pub fn is_overloaded(&self) -> bool {
        matches!(self, Response::Error { error, retryable: true, .. } if error == "overloaded")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    #[test]
    fn score_request_round_trip() {
        let req = Request::Score { id: Some(9), features: vec![0.0, -1.5, 0.25] };
        let line = req.to_line();
        assert!(line.ends_with('\n'));
        match Request::parse(line.trim()).unwrap() {
            Request::Score { id, features } => {
                assert_eq!(id, Some(9));
                assert_eq!(features, vec![0.0, -1.5, 0.25]);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // Without an id.
        match Request::parse(&Request::Score { id: None, features: vec![1.0] }.to_line()).unwrap()
        {
            Request::Score { id, .. } => assert_eq!(id, None),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn control_requests_round_trip() {
        assert!(matches!(Request::parse(&Request::Stats.to_line()).unwrap(), Request::Stats));
        assert!(matches!(Request::parse(&Request::Ping.to_line()).unwrap(), Request::Ping));
        let snapshot = ModelSnapshot {
            weights: vec![1.0, -2.0],
            var_sn: 3.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        };
        match Request::parse(&Request::Reload { snapshot: snapshot.clone() }.to_line()).unwrap() {
            Request::Reload { snapshot: back } => {
                assert_eq!(back.weights, snapshot.weights);
                assert_eq!(back.boundary, snapshot.boundary);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn request_parse_rejects_malformed_lines() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("{}").is_err(), "missing op");
        assert!(Request::parse(r#"{"op":"warp"}"#).is_err(), "unknown op");
        assert!(Request::parse(r#"{"op":"score"}"#).is_err(), "missing features");
        assert!(
            Request::parse(r#"{"op":"score","features":[1,"x"]}"#).is_err(),
            "non-numeric feature"
        );
        assert!(
            Request::parse(r#"{"op":"score","features":[1,1e999]}"#).is_err(),
            "non-finite feature must be rejected before it can poison a response"
        );
        assert!(Request::parse(r#"{"op":"reload"}"#).is_err(), "missing snapshot");
    }

    #[test]
    fn response_round_trips() {
        let r = Response::Score { id: Some(3), score: -0.75, features_evaluated: 41 };
        match Response::parse(r.to_line().trim()).unwrap() {
            Response::Score { id, score, features_evaluated } => {
                assert_eq!(id, Some(3));
                assert_eq!(score, -0.75);
                assert_eq!(features_evaluated, 41);
            }
            other => panic!("wrong variant {other:?}"),
        }
        match Response::parse(&Response::Reloaded { dim: 784 }.to_line()).unwrap() {
            Response::Reloaded { dim } => assert_eq!(dim, 784),
            other => panic!("wrong variant {other:?}"),
        }
        assert!(matches!(Response::parse(&Response::Pong.to_line()).unwrap(), Response::Pong));
    }

    #[test]
    fn stats_report_round_trip() {
        let report = StatsReport {
            served: 1000,
            avg_features: 93.5,
            early_exit_rate: 0.875,
            batches: 120,
            features_p50: 63,
            features_p90: 511,
            features_p99: 1023,
            accepted_conns: 5,
            overloaded: 17,
            protocol_errors: 2,
            reloads: 1,
            uptime_s: 4.5,
            req_per_s: 222.2,
        };
        match Response::parse(&Response::Stats(report).to_line()).unwrap() {
            Response::Stats(back) => assert_eq!(back, report),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn error_responses_flag_retryability() {
        let shed = Response::Error { id: None, error: "overloaded".into(), retryable: true };
        let parsed = Response::parse(&shed.to_line()).unwrap();
        assert!(parsed.is_overloaded());
        let fatal =
            Response::Error { id: Some(1), error: "dimension mismatch".into(), retryable: false };
        match Response::parse(&fatal.to_line()).unwrap() {
            Response::Error { id, error, retryable } => {
                assert_eq!(id, Some(1));
                assert!(error.contains("dimension"));
                assert!(!retryable);
            }
            other => panic!("wrong variant {other:?}"),
        }
        assert!(!Response::parse(&fatal.to_line()).unwrap().is_overloaded());
    }
}
