//! JSON wire protocol for the serving front-end, plus the protocol-v2
//! handshake.
//!
//! One compact JSON document per `\n`-terminated line, in both
//! directions. Std-only and deliberately boring: debuggable with `nc`,
//! parseable by any language, and friendly to line-oriented tooling.
//! See `docs/PROTOCOL.md` for the full spec (including the binary
//! framing in [`crate::server::frame`]).
//!
//! Requests:
//!
//! ```text
//! {"op":"score","features":[0.0,0.5,...],"id":7}   // dense; id optional
//! {"op":"score","idx":[3,17,40],"val":[0.5,-1.2,2.0]}  // sparse (v2 form)
//! {"op":"score","model":"digits-2v3","idx":[...],"val":[...]}  // routed
//! {"op":"classify","model":"digits","idx":[...],"val":[...]}   // all-pairs vote
//! {"op":"learn","y":1,"idx":[...],"val":[...]}     // online-training example
//! {"op":"score-batch","examples":[{"idx":[...],"val":[...]},...]}  // v6
//! {"op":"hello","proto":4}                         // framing negotiation
//! {"op":"stats"}
//! {"op":"models"}                                  // shard table
//! {"op":"reload","model":"digits-2v3","snapshot":{...ServingModel...}}
//! {"op":"add-model","name":"pair-4v9","snapshot":{...},"learn":true}  // v5
//! {"op":"remove-model","name":"pair-4v9"}          // v5
//! {"op":"ping"}
//! ```
//!
//! The sparse form carries strictly increasing `idx` with parallel
//! finite `val` and flows through the server **without densifying** —
//! the evaluator walks only the support. The optional `"model"` field
//! routes a request (or reload) to a named registry shard; omitting it
//! lands on the default shard, which is how single-model clients keep
//! working against a multi-model server. `classify` runs the attentive
//! all-pairs vote on an ensemble shard and answers with the predicted
//! class plus total features touched across voters. `learn` submits one
//! labeled example (`"y"` = ±1) to the routed shard's online trainer;
//! the trainer periodically publishes fresh snapshot generations into
//! the same hub the score path serves from, and a full learn queue
//! sheds with a retryable `overloaded` error. `add-model` registers a
//! brand-new shard at runtime (inline snapshot or ensemble; `"learn"`
//! attaches an online trainer warm-started from the model's weights)
//! and `remove-model` retires one — routes are swapped atomically, so
//! churn never stalls sibling shards. `hello` negotiates the framing
//! for the rest of the connection: asking for `"proto":2` (or higher)
//! switches both directions to the length-prefixed binary frames of
//! [`crate::server::frame`] — a grant of 3 additionally unlocks the
//! model-routed v3 frame ops, a grant of 4 the `LEARN_SPARSE` frame
//! (the learn *capability*; the JSON `learn` op works on any protocol
//! version), and a grant of 5 advertises the dynamic shard lifecycle
//! (`add-model` / `remove-model`, which also travel as JSON envelopes
//! on every framing), and a grant of 6 the batched scoring capability
//! (the binary `SCORE_BATCH` frame; the JSON `score-batch` op works on
//! any protocol version). Anything else stays on JSON lines, so v1
//! clients that never send `hello` are untouched.
//!
//! `score-batch` scores up to the server's `max_batch_examples`
//! payloads on one binary shard as a single queue admission, answering
//! with one per-example `results` row each carrying either the score
//! or that example's error — one bad example never poisons its
//! batchmates; whole-batch failures (unknown model, wrong kind,
//! overload) answer with a single plain error response.
//!
//! Responses always carry `"ok"`; errors carry `"error"` plus
//! `"retryable"` (`true` for `overloaded` shed responses, which the
//! client may retry after backing off):
//!
//! ```text
//! {"ok":true,"op":"score","id":7,"score":1.25,"features_evaluated":34}
//! {"ok":true,"op":"classify","label":3,"votes":9,"voters":45,"features_evaluated":1210}
//! {"ok":true,"op":"learn","gen":2,"seen":128}
//! {"ok":true,"op":"score-batch","results":[{"score":1.25,"features_evaluated":34},
//!                                          {"error":"dimension-mismatch"}]}
//! {"ok":true,"op":"hello","proto":4,"gen":1,"dim":784}
//! {"ok":true,"op":"stats", ...StatsReport...}
//! {"ok":true,"op":"models","models":[{"name":"default","id":0,...},...]}
//! {"ok":true,"op":"reload","dim":784}
//! {"ok":true,"op":"add-model","name":"pair-4v9","id":3,"dim":784}
//! {"ok":true,"op":"remove-model","name":"pair-4v9"}
//! {"ok":true,"op":"pong"}
//! {"ok":false,"error":"overloaded","retryable":true}
//! ```
//!
//! Responses on one connection are emitted in request order, so clients
//! can pipeline without correlating ids (ids are still echoed for
//! clients that want them).

use crate::coordinator::service::{Features, Lane, ServingModel, VoterVote};
use crate::util::json::Json;

/// Protocol version 2: binary framing, single-model ops.
pub const PROTO_V2: u32 = 2;
/// Protocol version 3: binary framing plus the model-routed v3 frame
/// ops (dense score, u32-indexed sparse score, classify).
pub const PROTO_V3: u32 = 3;
/// Protocol version 4: v3 plus the online-learning capability (the
/// binary `LEARN_SPARSE` frame and its `LEARN_ACK`).
pub const PROTO_V4: u32 = 4;
/// Protocol version 5: v4 plus the dynamic shard lifecycle capability
/// (`add-model` / `remove-model` control ops; a v5 grant is how
/// clients discover the server supports them).
pub const PROTO_V5: u32 = 5;
/// Protocol version 6: v5 plus the batched scoring capability (the
/// binary `SCORE_BATCH` frame and its `SCORE_BATCH_RESP`; a v6 grant
/// is how clients discover the server accepts batches and respects its
/// advertised `max_batch_examples`).
pub const PROTO_V6: u32 = 6;
/// Highest protocol version this build speaks: v6 plus the overload
/// brownout capability — per-request deadlines (`deadline_ms`) and
/// admission-lane overrides (`priority`) on score/classify/score-batch,
/// the retryable `deadline-exceeded` error, the `degraded` response
/// flag, and the binary EX frame ops that carry the same fields.
pub const PROTO_V7: u32 = 7;

/// A client → server message.
#[derive(Debug, Clone)]
pub enum Request {
    /// Negotiate the connection's framing (`proto` = requested version).
    Hello {
        /// Requested protocol version (1 = JSON lines, 2 = binary
        /// frames, 3 = binary frames + model-routed ops, 4 = v3 plus
        /// the `LEARN_SPARSE` capability).
        proto: u32,
    },
    /// Score one feature payload (dense or sparse) on a binary shard.
    Score {
        /// Optional client-chosen correlation id, echoed in the response.
        id: Option<u64>,
        /// Registry shard to route to (`None` = the default shard).
        model: Option<String>,
        /// The payload; sparse payloads are scored without densifying.
        features: Features,
        /// Optional relative deadline (protocol v7): work still queued
        /// `deadline_ms` after admission is answered with the retryable
        /// `deadline-exceeded` error instead of being scored. `None`
        /// (or 0) falls back to the server's configured default.
        deadline_ms: Option<u64>,
        /// Optional admission-lane override (protocol v7); `None`
        /// takes the op default (singles → interactive).
        priority: Option<Lane>,
    },
    /// Run the attentive all-pairs vote on an ensemble shard.
    Classify {
        /// Optional client-chosen correlation id, echoed in the response.
        id: Option<u64>,
        /// Registry shard to route to (`None` = the default shard).
        model: Option<String>,
        /// The payload; each voter early-exits on it independently.
        features: Features,
        /// Ask for the per-voter cost breakdown (`"verbose":true`): the
        /// response carries one row per 1-vs-1 voter attributing vote
        /// and features-touched, so clients can see where the attentive
        /// budget went.
        verbose: bool,
        /// Optional relative deadline (protocol v7); see
        /// [`Request::Score::deadline_ms`].
        deadline_ms: Option<u64>,
        /// Optional admission-lane override (protocol v7).
        priority: Option<Lane>,
    },
    /// Score a batch of examples on one binary shard as a single queue
    /// admission (the protocol-v6 `SCORE_BATCH` capability's JSON
    /// twin). Examples are scored back-to-back in submission order, so
    /// the batch is bit-identical to the same examples sent as single
    /// `score` requests.
    ScoreBatch {
        /// Optional client-chosen correlation id, echoed in the response.
        id: Option<u64>,
        /// Registry shard to route to (`None` = the default shard).
        model: Option<String>,
        /// The payloads, each dense or sparse. Per-example validation
        /// happens at admission so one malformed example degrades to
        /// its own error row instead of failing the batch.
        examples: Vec<Features>,
        /// Optional relative deadline (protocol v7); an expired batch
        /// is shed whole — every row answers `deadline-exceeded`.
        deadline_ms: Option<u64>,
        /// Optional admission-lane override (protocol v7); `None`
        /// takes the op default (batches → bulk).
        priority: Option<Lane>,
    },
    /// Submit one labeled example to the routed shard's online trainer.
    Learn {
        /// Optional client-chosen correlation id, echoed in the response.
        id: Option<u64>,
        /// Registry shard to route to (`None` = the default shard).
        model: Option<String>,
        /// Example label, ±1.
        label: i8,
        /// The payload; sparse payloads are densified by the trainer,
        /// never on the wire path.
        features: Features,
    },
    /// Fetch the server's live statistics.
    Stats,
    /// List the registry's model shards (name, wire id, kind, gen, dim).
    Models,
    /// Hot-swap one shard's serving model.
    Reload {
        /// Registry shard to swap (`None` = the default shard).
        model: Option<String>,
        /// The replacement model (binary snapshot or ensemble).
        snapshot: ServingModel,
    },
    /// Register a brand-new shard at runtime (protocol v5 capability).
    AddModel {
        /// Name of the new shard (must not collide with a live shard).
        name: String,
        /// The model it serves (binary snapshot or ensemble).
        snapshot: ServingModel,
        /// Attach an online trainer, warm-started from the snapshot's
        /// weights, so the new shard accepts `learn` traffic.
        learn: bool,
    },
    /// Retire a shard at runtime (protocol v5 capability). The default
    /// shard cannot be removed.
    RemoveModel {
        /// Name of the shard to retire.
        name: String,
    },
    /// Liveness probe.
    Ping,
}

/// Parse a JSON array of finite numbers (shared by the dense and sparse
/// score forms).
fn parse_f64_array(v: &Json, what: &str) -> Result<Vec<f64>, String> {
    let arr = v.as_arr().ok_or_else(|| format!("score: {what} must be an array"))?;
    arr.iter()
        .map(|x| x.as_f64().ok_or_else(|| format!("score: non-numeric {what} entry")))
        .collect()
}

/// Extract a dense-or-sparse feature payload from a request object (or
/// one `score-batch` example object). Structural screening
/// ([`Features::validate`]) is the caller's call: single-example ops
/// reject the whole request, batch admission degrades to a per-example
/// error row.
fn parse_features(v: &Json, op: &str) -> Result<Features, String> {
    let dense = v.get("features");
    let sparse = (v.get("idx"), v.get("val"));
    match (dense, sparse) {
        (Some(_), (Some(_), _) | (_, Some(_))) => {
            Err(format!("{op}: give either features or idx/val, not both"))
        }
        (Some(arr), _) => Ok(Features::Dense(parse_f64_array(arr, "features")?)),
        (None, (Some(idx), Some(val))) => {
            let idx = idx
                .as_arr()
                .ok_or_else(|| format!("{op}: idx must be an array"))?
                .iter()
                .map(|x| {
                    x.as_u64()
                        .filter(|&i| i <= u32::MAX as u64)
                        .map(|i| i as u32)
                        .ok_or_else(|| format!("{op}: bad idx entry"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Features::Sparse { idx, val: parse_f64_array(val, "val")? })
        }
        (None, (Some(_), None)) => Err(format!("{op}: idx without val")),
        (None, (None, Some(_))) => Err(format!("{op}: val without idx")),
        (None, (None, None)) => Err(format!("{op}: missing features")),
    }
}

/// Extract the protocol-v7 admission options (`deadline_ms`,
/// `priority`) from a request object. Both are optional; a present
/// `priority` must name a known lane.
fn parse_admission(
    v: &Json,
    op: &str,
) -> Result<(Option<u64>, Option<Lane>), String> {
    let deadline_ms = match v.get("deadline_ms") {
        None => None,
        Some(x) => {
            Some(x.as_u64().ok_or_else(|| format!("{op}: bad deadline_ms"))?)
        }
    };
    let priority = match v.get("priority").map(|p| p.as_str()) {
        None => None,
        Some(Some("interactive")) => Some(Lane::Interactive),
        Some(Some("bulk")) => Some(Lane::Bulk),
        Some(_) => {
            return Err(format!("{op}: priority must be \"interactive\" or \"bulk\""))
        }
    };
    Ok((deadline_ms, priority))
}

impl Request {
    /// Parse one request line (the versioned parser: accepts both the
    /// v1 dense and the v2 sparse score forms on any connection).
    pub fn parse(line: &str) -> Result<Request, String> {
        let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        let op = v.get("op").and_then(|o| o.as_str()).ok_or("missing op")?;
        match op {
            "hello" => {
                let proto = v.get("proto").and_then(|x| x.as_u64()).unwrap_or(1);
                Ok(Request::Hello { proto: proto.min(u32::MAX as u64) as u32 })
            }
            op @ ("score" | "classify" | "learn") => {
                let id = v.get("id").and_then(|x| x.as_u64());
                let model = v.get("model").and_then(|s| s.as_str()).map(str::to_string);
                let features = parse_features(&v, op)?;
                // Reject structural damage (unsorted/duplicate indices,
                // length mismatch) and non-finite values here: a
                // non-finite margin could not be serialized back as
                // valid JSON, and a malformed support must never reach
                // the margin walker.
                features.validate().map_err(|e| format!("{op}: {e}"))?;
                let verbose = v.get("verbose").and_then(|b| b.as_bool()).unwrap_or(false);
                if verbose && op != "classify" {
                    return Err(format!("{op}: verbose is a classify-only flag"));
                }
                let (deadline_ms, priority) = parse_admission(&v, op)?;
                if op == "learn" && (deadline_ms.is_some() || priority.is_some()) {
                    return Err(
                        "learn: deadline_ms/priority are scoring-only fields".into()
                    );
                }
                match op {
                    "classify" => Ok(Request::Classify {
                        id,
                        model,
                        features,
                        verbose,
                        deadline_ms,
                        priority,
                    }),
                    "learn" => {
                        let y = v
                            .get("y")
                            .and_then(|x| x.as_i64())
                            .ok_or("learn: missing label y")?;
                        if y != 1 && y != -1 {
                            return Err(format!("learn: y must be 1 or -1, got {y}"));
                        }
                        Ok(Request::Learn { id, model, label: y as i8, features })
                    }
                    _ => Ok(Request::Score { id, model, features, deadline_ms, priority }),
                }
            }
            "score-batch" => {
                let id = v.get("id").and_then(|x| x.as_u64());
                let model = v.get("model").and_then(|s| s.as_str()).map(str::to_string);
                let rows = v
                    .get("examples")
                    .and_then(|a| a.as_arr())
                    .ok_or("score-batch: missing examples")?;
                let examples = rows
                    .iter()
                    .map(|ex| parse_features(ex, "score-batch"))
                    .collect::<Result<Vec<_>, _>>()?;
                let (deadline_ms, priority) = parse_admission(&v, "score-batch")?;
                Ok(Request::ScoreBatch { id, model, examples, deadline_ms, priority })
            }
            "stats" => Ok(Request::Stats),
            "models" => Ok(Request::Models),
            "reload" => Ok(Request::Reload {
                model: v.get("model").and_then(|s| s.as_str()).map(str::to_string),
                snapshot: ServingModel::from_json(
                    v.get("snapshot").ok_or("reload: missing snapshot")?,
                )?,
            }),
            "add-model" => Ok(Request::AddModel {
                name: v
                    .get("name")
                    .and_then(|s| s.as_str())
                    .ok_or("add-model: missing name")?
                    .to_string(),
                snapshot: ServingModel::from_json(
                    v.get("snapshot").ok_or("add-model: missing snapshot")?,
                )?,
                learn: v.get("learn").and_then(|b| b.as_bool()).unwrap_or(false),
            }),
            "remove-model" => Ok(Request::RemoveModel {
                name: v
                    .get("name")
                    .and_then(|s| s.as_str())
                    .ok_or("remove-model: missing name")?
                    .to_string(),
            }),
            "ping" => Ok(Request::Ping),
            other => Err(format!("unknown op {other:?}")),
        }
    }

    /// Append the dense-or-sparse feature fields to a request object.
    fn push_features(pairs: &mut Vec<(&'static str, Json)>, features: &Features) {
        match features {
            Features::Dense(x) => pairs.push((
                "features",
                Json::Arr(x.iter().map(|&f| Json::Num(f)).collect()),
            )),
            Features::Sparse { idx, val } => {
                pairs.push((
                    "idx",
                    Json::Arr(idx.iter().map(|&i| Json::Num(i as f64)).collect()),
                ));
                pairs.push((
                    "val",
                    Json::Arr(val.iter().map(|&f| Json::Num(f)).collect()),
                ));
            }
        }
    }

    /// Append the optional protocol-v7 admission fields to a request
    /// object (omitted entirely when unset, so pre-v7 servers and
    /// byte-level captures are unchanged).
    fn push_admission(
        pairs: &mut Vec<(&'static str, Json)>,
        deadline_ms: &Option<u64>,
        priority: &Option<Lane>,
    ) {
        if let Some(ms) = deadline_ms {
            pairs.push(("deadline_ms", Json::Num(*ms as f64)));
        }
        if let Some(lane) = priority {
            let name = match lane {
                Lane::Interactive => "interactive",
                Lane::Bulk => "bulk",
            };
            pairs.push(("priority", Json::Str(name.into())));
        }
    }

    /// Serialize (client side).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Hello { proto } => Json::obj([
                ("op", Json::Str("hello".into())),
                ("proto", Json::Num(*proto as f64)),
            ]),
            Request::Score { id, model, features, deadline_ms, priority }
            | Request::Classify { id, model, features, deadline_ms, priority, .. } => {
                let op = match self {
                    Request::Classify { .. } => "classify",
                    _ => "score",
                };
                let mut pairs = vec![("op", Json::Str(op.into()))];
                if let Request::Classify { verbose: true, .. } = self {
                    pairs.push(("verbose", Json::Bool(true)));
                }
                if let Some(model) = model {
                    pairs.push(("model", Json::Str(model.clone())));
                }
                Self::push_admission(&mut pairs, deadline_ms, priority);
                Self::push_features(&mut pairs, features);
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Request::ScoreBatch { id, model, examples, deadline_ms, priority } => {
                let mut pairs = vec![("op", Json::Str("score-batch".into()))];
                if let Some(model) = model {
                    pairs.push(("model", Json::Str(model.clone())));
                }
                Self::push_admission(&mut pairs, deadline_ms, priority);
                pairs.push((
                    "examples",
                    Json::Arr(
                        examples
                            .iter()
                            .map(|features| {
                                let mut row = Vec::new();
                                Self::push_features(&mut row, features);
                                Json::obj(row)
                            })
                            .collect(),
                    ),
                ));
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Request::Learn { id, model, label, features } => {
                let mut pairs = vec![
                    ("op", Json::Str("learn".into())),
                    ("y", Json::Num(*label as f64)),
                ];
                if let Some(model) = model {
                    pairs.push(("model", Json::Str(model.clone())));
                }
                Self::push_features(&mut pairs, features);
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Request::Stats => Json::obj([("op", Json::Str("stats".into()))]),
            Request::Models => Json::obj([("op", Json::Str("models".into()))]),
            Request::Reload { model, snapshot } => {
                let mut pairs = vec![("op", Json::Str("reload".into()))];
                if let Some(model) = model {
                    pairs.push(("model", Json::Str(model.clone())));
                }
                pairs.push(("snapshot", snapshot.to_json()));
                Json::obj(pairs)
            }
            Request::AddModel { name, snapshot, learn } => {
                let mut pairs = vec![
                    ("op", Json::Str("add-model".into())),
                    ("name", Json::Str(name.clone())),
                    ("snapshot", snapshot.to_json()),
                ];
                if *learn {
                    pairs.push(("learn", Json::Bool(true)));
                }
                Json::obj(pairs)
            }
            Request::RemoveModel { name } => Json::obj([
                ("op", Json::Str("remove-model".into())),
                ("name", Json::Str(name.clone())),
            ]),
            Request::Ping => Json::obj([("op", Json::Str("ping".into()))]),
        }
    }

    /// One wire line (compact JSON + newline).
    pub fn to_line(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }
}

/// Served/bytes counters for one wire class (protocol version ×
/// encoding), exposed by the `stats` op so protocol-migration progress
/// and routing skew are observable in production.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Score/classify responses emitted on this wire class.
    pub served: u64,
    /// Response bytes written on this wire class (all ops).
    pub bytes: u64,
}

impl WireStats {
    fn to_json(self) -> Json {
        Json::obj([
            ("served", Json::Num(self.served as f64)),
            ("bytes", Json::Num(self.bytes as f64)),
        ])
    }

    fn from_json(v: Option<&Json>) -> WireStats {
        let int = |k: &str| {
            v.and_then(|w| w.get(k)).and_then(|x| x.as_u64()).unwrap_or(0)
        };
        WireStats { served: int("served"), bytes: int("bytes") }
    }
}

/// Per-model-shard slice of the stats report.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelStatsReport {
    /// Shard name.
    pub name: String,
    /// Requests this shard scored/classified.
    pub served: u64,
    /// Mean features touched per request on this shard.
    pub avg_features: f64,
    /// Fraction of this shard's requests that exited early.
    pub early_exit_rate: f64,
    /// Shard serving generation.
    pub gen: u32,
    /// Hot reloads applied to this shard (wire `reload` + trainer
    /// publishes alike — every generation swap).
    pub reloads: u64,
    /// Whether an online trainer is attached to this shard.
    pub trainer: bool,
    /// Examples the trainer accepted off the wire.
    pub learn_examples: u64,
    /// Accepted examples that updated the live learner.
    pub learn_updates: u64,
    /// Examples shed because the learn queue was full.
    pub learn_sheds: u64,
    /// Snapshot generations the trainer published into the hub.
    pub learn_publishes: u64,
    /// Features the learner evaluated while training (the attentive
    /// budget actually spent on the learn path).
    pub learn_features: u64,
    /// Lifecycle state (see [`ModelEntry::state`]).
    pub state: String,
}

impl ModelStatsReport {
    fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::Str(self.name.clone())),
            ("state", Json::Str(self.state.clone())),
            ("served", Json::Num(self.served as f64)),
            ("avg_features", Json::Num(self.avg_features)),
            ("early_exit_rate", Json::Num(self.early_exit_rate)),
            ("gen", Json::Num(self.gen as f64)),
            ("reloads", Json::Num(self.reloads as f64)),
            ("trainer", Json::Bool(self.trainer)),
            ("learn_examples", Json::Num(self.learn_examples as f64)),
            ("learn_updates", Json::Num(self.learn_updates as f64)),
            ("learn_sheds", Json::Num(self.learn_sheds as f64)),
            ("learn_publishes", Json::Num(self.learn_publishes as f64)),
            ("learn_features", Json::Num(self.learn_features as f64)),
        ])
    }

    fn from_json(v: &Json) -> ModelStatsReport {
        let int = |k: &str| v.get(k).and_then(|x| x.as_u64()).unwrap_or(0);
        ModelStatsReport {
            name: v.get("name").and_then(|s| s.as_str()).unwrap_or("").to_string(),
            state: v.get("state").and_then(|s| s.as_str()).unwrap_or("serving").to_string(),
            served: int("served"),
            avg_features: v.get("avg_features").and_then(|x| x.as_f64()).unwrap_or(0.0),
            early_exit_rate: v.get("early_exit_rate").and_then(|x| x.as_f64()).unwrap_or(0.0),
            gen: int("gen") as u32,
            reloads: int("reloads"),
            trainer: v.get("trainer").and_then(|b| b.as_bool()).unwrap_or(false),
            learn_examples: int("learn_examples"),
            learn_updates: int("learn_updates"),
            learn_sheds: int("learn_sheds"),
            learn_publishes: int("learn_publishes"),
            learn_features: int("learn_features"),
        }
    }
}

/// Server statistics exposed by the `stats` op.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatsReport {
    /// Requests scored.
    pub served: u64,
    /// Mean features touched per scored request.
    pub avg_features: f64,
    /// Fraction of scored requests that exited early.
    pub early_exit_rate: f64,
    /// Worker batches drained.
    pub batches: u64,
    /// Approx. features-touched percentiles (histogram upper edges).
    pub features_p50: u64,
    /// 90th percentile.
    pub features_p90: u64,
    /// 99th percentile.
    pub features_p99: u64,
    /// Connections accepted since start.
    pub accepted_conns: u64,
    /// Requests shed with an `overloaded` response.
    pub overloaded: u64,
    /// Batches refused by the adaptive admission cap (queue under
    /// pressure; retryable) — distinct from whole-queue `overloaded`
    /// sheds and from the fixed batch-size ceiling.
    pub batch_shed: u64,
    /// Worker evaluations that panicked and were contained (the worker
    /// respawned; the request answered with a retryable `internal`
    /// error). Not counted in `served`.
    pub worker_panics: u64,
    /// Requests (counted per example for batches) whose deadline had
    /// already expired at dequeue and were answered with the retryable
    /// `deadline-exceeded` error instead of being scored.
    pub deadline_sheds: u64,
    /// Responses answered under a brownout tier (flagged `degraded`).
    pub degraded_responses: u64,
    /// Current brownout tier (0 = normal, 1–2 = tightened thresholds,
    /// 3 = shed: bulk admissions refused). Max across shards.
    pub brownout_tier: u64,
    /// Brownout tier transitions since start (both directions).
    pub tier_transitions: u64,
    /// Lines that failed to parse as a request.
    pub protocol_errors: u64,
    /// Hot model reloads applied.
    pub reloads: u64,
    /// Seconds since the server started.
    pub uptime_s: f64,
    /// Scored requests per second over the whole uptime.
    pub req_per_s: f64,
    /// v1 JSON-lines traffic.
    pub wire_v1: WireStats,
    /// v2+ JSON-envelope-frame traffic.
    pub wire_v2_json: WireStats,
    /// v2+ native binary-frame traffic.
    pub wire_v2_binary: WireStats,
    /// Per-shard counters, in wire-id order (default shard first).
    pub models: Vec<ModelStatsReport>,
}

impl StatsReport {
    /// Serialize the payload fields (caller adds the envelope).
    fn payload(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("served", Json::Num(self.served as f64)),
            ("avg_features", Json::Num(self.avg_features)),
            ("early_exit_rate", Json::Num(self.early_exit_rate)),
            ("batches", Json::Num(self.batches as f64)),
            ("features_p50", Json::Num(self.features_p50 as f64)),
            ("features_p90", Json::Num(self.features_p90 as f64)),
            ("features_p99", Json::Num(self.features_p99 as f64)),
            ("accepted_conns", Json::Num(self.accepted_conns as f64)),
            ("overloaded", Json::Num(self.overloaded as f64)),
            ("batch_shed", Json::Num(self.batch_shed as f64)),
            ("worker_panics", Json::Num(self.worker_panics as f64)),
            ("deadline_sheds", Json::Num(self.deadline_sheds as f64)),
            ("degraded_responses", Json::Num(self.degraded_responses as f64)),
            ("brownout_tier", Json::Num(self.brownout_tier as f64)),
            ("tier_transitions", Json::Num(self.tier_transitions as f64)),
            ("protocol_errors", Json::Num(self.protocol_errors as f64)),
            ("reloads", Json::Num(self.reloads as f64)),
            ("uptime_s", Json::Num(self.uptime_s)),
            ("req_per_s", Json::Num(self.req_per_s)),
            (
                "wire",
                Json::obj([
                    ("v1", self.wire_v1.to_json()),
                    ("v2-json", self.wire_v2_json.to_json()),
                    ("v2-binary", self.wire_v2_binary.to_json()),
                ]),
            ),
            ("models", Json::Arr(self.models.iter().map(ModelStatsReport::to_json).collect())),
        ]
    }

    /// Parse the payload fields (missing fields default to zero, so the
    /// report stays forward-compatible when the server grows counters).
    pub fn from_json(v: &Json) -> StatsReport {
        let num = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
        let int = |k: &str| v.get(k).and_then(|x| x.as_u64()).unwrap_or(0);
        let wire = v.get("wire");
        StatsReport {
            wire_v1: WireStats::from_json(wire.and_then(|w| w.get("v1"))),
            wire_v2_json: WireStats::from_json(wire.and_then(|w| w.get("v2-json"))),
            wire_v2_binary: WireStats::from_json(wire.and_then(|w| w.get("v2-binary"))),
            models: v
                .get("models")
                .and_then(|a| a.as_arr())
                .map(|arr| arr.iter().map(ModelStatsReport::from_json).collect())
                .unwrap_or_default(),
            served: int("served"),
            avg_features: num("avg_features"),
            early_exit_rate: num("early_exit_rate"),
            batches: int("batches"),
            features_p50: int("features_p50"),
            features_p90: int("features_p90"),
            features_p99: int("features_p99"),
            accepted_conns: int("accepted_conns"),
            overloaded: int("overloaded"),
            batch_shed: int("batch_shed"),
            worker_panics: int("worker_panics"),
            deadline_sheds: int("deadline_sheds"),
            degraded_responses: int("degraded_responses"),
            brownout_tier: int("brownout_tier"),
            tier_transitions: int("tier_transitions"),
            protocol_errors: int("protocol_errors"),
            reloads: int("reloads"),
            uptime_s: num("uptime_s"),
            req_per_s: num("req_per_s"),
        }
    }
}

/// One row of the `models` op: a registry shard's identity and live
/// serving state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelEntry {
    /// Shard name (JSON routing key).
    pub name: String,
    /// Interned wire id (binary v3 routing key; 0 = default shard).
    pub id: u16,
    /// `"binary"` or `"ensemble"`.
    pub kind: String,
    /// Serving generation.
    pub gen: u32,
    /// Feature dimensionality.
    pub dim: usize,
    /// Voters behind the shard (0 for binary).
    pub voters: usize,
    /// Whether the shard accepts `learn` traffic (trainer attached).
    pub learn: bool,
    /// Lifecycle state: `"serving"`, `"draining"`, or
    /// `"removed-pending-drain"` (the latter two only while a v5
    /// removal quiesces the shard).
    pub state: String,
}

impl ModelEntry {
    fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::Str(self.name.clone())),
            ("id", Json::Num(self.id as f64)),
            ("kind", Json::Str(self.kind.clone())),
            ("gen", Json::Num(self.gen as f64)),
            ("dim", Json::Num(self.dim as f64)),
            ("voters", Json::Num(self.voters as f64)),
            ("learn", Json::Bool(self.learn)),
            ("state", Json::Str(self.state.clone())),
        ])
    }

    fn from_json(v: &Json) -> Result<ModelEntry, String> {
        Ok(ModelEntry {
            name: v.get("name").and_then(|s| s.as_str()).ok_or("models: missing name")?.into(),
            id: v.get("id").and_then(|x| x.as_u64()).ok_or("models: missing id")? as u16,
            kind: v.get("kind").and_then(|s| s.as_str()).unwrap_or("binary").into(),
            gen: v.get("gen").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
            dim: v.get("dim").and_then(|x| x.as_usize()).unwrap_or(0),
            voters: v.get("voters").and_then(|x| x.as_usize()).unwrap_or(0),
            learn: v.get("learn").and_then(|b| b.as_bool()).unwrap_or(false),
            state: v.get("state").and_then(|s| s.as_str()).unwrap_or("serving").into(),
        })
    }
}

/// One per-example row of a `score-batch` response.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchRow {
    /// `None` = scored; `Some` carries the kebab-case error name for
    /// this one example (its batchmates are unaffected).
    pub error: Option<String>,
    /// Signed margin estimate (0.0 on error rows).
    pub score: f64,
    /// Features evaluated before the early exit (0 on error rows).
    pub features_evaluated: usize,
}

impl BatchRow {
    /// A scored row.
    pub fn ok(score: f64, features_evaluated: usize) -> BatchRow {
        BatchRow { error: None, score, features_evaluated }
    }

    /// A per-example error row.
    pub fn err(error: impl Into<String>) -> BatchRow {
        BatchRow { error: Some(error.into()), score: 0.0, features_evaluated: 0 }
    }
}

/// A server → client message.
#[derive(Debug, Clone)]
pub enum Response {
    /// Handshake answer: the framing the rest of the connection uses.
    Hello {
        /// Granted protocol version (may be lower than requested).
        proto: u32,
        /// Current serving model generation (see v2 generation pinning).
        gen: u32,
        /// Serving model dimensionality.
        dim: usize,
    },
    /// A scored request.
    Score {
        /// Echo of the request id, if one was sent.
        id: Option<u64>,
        /// Signed margin estimate; the prediction is its sign.
        score: f64,
        /// Features evaluated before the early exit.
        features_evaluated: usize,
        /// Scored under a brownout tier (protocol v7): the early-exit
        /// thresholds were tightened, trading accuracy for latency.
        /// Omitted from the wire when false.
        degraded: bool,
    },
    /// A classified request (attentive all-pairs vote).
    Classify {
        /// Echo of the request id, if one was sent.
        id: Option<u64>,
        /// Predicted class (vote winner; ties break toward the smaller
        /// label).
        label: i64,
        /// Votes the winner collected.
        votes: u32,
        /// Voters consulted.
        voters: u32,
        /// Features evaluated, summed across voters.
        features_evaluated: usize,
        /// Answered under a brownout tier (protocol v7).
        degraded: bool,
    },
    /// A classified request with the per-voter cost breakdown
    /// (`classify` with `"verbose":true`). Same vote as
    /// [`Response::Classify`], plus one row per 1-vs-1 voter.
    ClassifyVerbose {
        /// Echo of the request id, if one was sent.
        id: Option<u64>,
        /// Predicted class (vote winner; ties break toward the smaller
        /// label).
        label: i64,
        /// Votes the winner collected.
        votes: u32,
        /// Voters consulted.
        voters: u32,
        /// Features evaluated, summed across voters.
        features_evaluated: usize,
        /// Per-voter rows, in pair-enumeration order.
        per_voter: Vec<VoterVote>,
        /// Answered under a brownout tier (protocol v7).
        degraded: bool,
    },
    /// A scored batch: one row per submitted example, in submission
    /// order, each carrying its own score or error.
    ScoreBatch {
        /// Echo of the request id, if one was sent.
        id: Option<u64>,
        /// Per-example outcome rows, in submission order.
        results: Vec<BatchRow>,
        /// At least one row was scored under a brownout tier
        /// (protocol v7). Omitted from the wire when false.
        degraded: bool,
    },
    /// A learn example was accepted by the routed shard's trainer.
    Learned {
        /// Echo of the request id, if one was sent.
        id: Option<u64>,
        /// Shard serving generation at ack time; watching it grow is
        /// how clients observe trainer publishes land.
        gen: u32,
        /// Cumulative examples this shard's trainer has accepted.
        seen: u64,
    },
    /// Live statistics.
    Stats(StatsReport),
    /// The registry's shard table.
    Models(Vec<ModelEntry>),
    /// A hot reload was applied; `dim` is the new model's dimensionality.
    Reloaded {
        /// New feature dimensionality.
        dim: usize,
    },
    /// A v5 `add-model` landed: the shard is live and routable.
    Added {
        /// Name of the new shard.
        name: String,
        /// Interned wire id the registry assigned (binary routing key).
        id: u16,
        /// The new shard's feature dimensionality.
        dim: usize,
    },
    /// A v5 `remove-model` landed: the shard is unrouted and draining.
    Removed {
        /// Name of the retired shard.
        name: String,
    },
    /// Liveness answer.
    Pong,
    /// The request failed. `retryable` marks shed load (`overloaded`).
    Error {
        /// Echo of the request id, if known.
        id: Option<u64>,
        /// What went wrong.
        error: String,
        /// Whether retrying later can succeed (backpressure shed).
        retryable: bool,
    },
}

impl Response {
    /// Serialize (server side).
    pub fn to_json(&self) -> Json {
        match self {
            Response::Hello { proto, gen, dim } => Json::obj([
                ("ok", Json::Bool(true)),
                ("op", Json::Str("hello".into())),
                ("proto", Json::Num(*proto as f64)),
                ("gen", Json::Num(*gen as f64)),
                ("dim", Json::Num(*dim as f64)),
            ]),
            Response::Score { id, score, features_evaluated, degraded } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("op", Json::Str("score".into())),
                    ("score", Json::Num(*score)),
                    ("features_evaluated", Json::Num(*features_evaluated as f64)),
                ];
                if *degraded {
                    pairs.push(("degraded", Json::Bool(true)));
                }
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Response::Classify { id, label, votes, voters, features_evaluated, degraded } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("op", Json::Str("classify".into())),
                    ("label", Json::Num(*label as f64)),
                    ("votes", Json::Num(*votes as f64)),
                    ("voters", Json::Num(*voters as f64)),
                    ("features_evaluated", Json::Num(*features_evaluated as f64)),
                ];
                if *degraded {
                    pairs.push(("degraded", Json::Bool(true)));
                }
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Response::ClassifyVerbose {
                id,
                label,
                votes,
                voters,
                features_evaluated,
                per_voter,
                degraded,
            } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("op", Json::Str("classify".into())),
                    ("label", Json::Num(*label as f64)),
                    ("votes", Json::Num(*votes as f64)),
                    ("voters", Json::Num(*voters as f64)),
                    ("features_evaluated", Json::Num(*features_evaluated as f64)),
                    (
                        "per_voter",
                        Json::Arr(
                            per_voter
                                .iter()
                                .map(|row| {
                                    Json::obj([
                                        ("pos", Json::Num(row.pos as f64)),
                                        ("neg", Json::Num(row.neg as f64)),
                                        ("vote", Json::Num(row.vote as f64)),
                                        ("features", Json::Num(row.features as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ];
                if *degraded {
                    pairs.push(("degraded", Json::Bool(true)));
                }
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Response::ScoreBatch { id, results, degraded } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("op", Json::Str("score-batch".into())),
                    (
                        "results",
                        Json::Arr(
                            results
                                .iter()
                                .map(|row| match &row.error {
                                    Some(e) => Json::obj([("error", Json::Str(e.clone()))]),
                                    None => Json::obj([
                                        ("score", Json::Num(row.score)),
                                        (
                                            "features_evaluated",
                                            Json::Num(row.features_evaluated as f64),
                                        ),
                                    ]),
                                })
                                .collect(),
                        ),
                    ),
                ];
                if *degraded {
                    pairs.push(("degraded", Json::Bool(true)));
                }
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Response::Learned { id, gen, seen } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("op", Json::Str("learn".into())),
                    ("gen", Json::Num(*gen as f64)),
                    ("seen", Json::Num(*seen as f64)),
                ];
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
            Response::Stats(report) => {
                let mut pairs =
                    vec![("ok", Json::Bool(true)), ("op", Json::Str("stats".into()))];
                pairs.extend(report.payload());
                Json::obj(pairs)
            }
            Response::Models(entries) => Json::obj([
                ("ok", Json::Bool(true)),
                ("op", Json::Str("models".into())),
                ("models", Json::Arr(entries.iter().map(ModelEntry::to_json).collect())),
            ]),
            Response::Reloaded { dim } => Json::obj([
                ("ok", Json::Bool(true)),
                ("op", Json::Str("reload".into())),
                ("dim", Json::Num(*dim as f64)),
            ]),
            Response::Added { name, id, dim } => Json::obj([
                ("ok", Json::Bool(true)),
                ("op", Json::Str("add-model".into())),
                ("name", Json::Str(name.clone())),
                ("id", Json::Num(*id as f64)),
                ("dim", Json::Num(*dim as f64)),
            ]),
            Response::Removed { name } => Json::obj([
                ("ok", Json::Bool(true)),
                ("op", Json::Str("remove-model".into())),
                ("name", Json::Str(name.clone())),
            ]),
            Response::Pong => {
                Json::obj([("ok", Json::Bool(true)), ("op", Json::Str("pong".into()))])
            }
            Response::Error { id, error, retryable } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(error.clone())),
                    ("retryable", Json::Bool(*retryable)),
                ];
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
        }
    }

    /// One wire line (compact JSON + newline).
    pub fn to_line(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }

    /// Parse one response line (client side).
    pub fn parse(line: &str) -> Result<Response, String> {
        let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        let ok = v.get("ok").and_then(|b| b.as_bool()).ok_or("missing ok")?;
        if !ok {
            return Ok(Response::Error {
                id: v.get("id").and_then(|x| x.as_u64()),
                error: v
                    .get("error")
                    .and_then(|s| s.as_str())
                    .unwrap_or("unknown error")
                    .to_string(),
                retryable: v.get("retryable").and_then(|b| b.as_bool()).unwrap_or(false),
            });
        }
        match v.get("op").and_then(|o| o.as_str()).ok_or("missing op")? {
            "hello" => Ok(Response::Hello {
                proto: v
                    .get("proto")
                    .and_then(|x| x.as_u64())
                    .ok_or("hello: missing proto")? as u32,
                gen: v.get("gen").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
                dim: v.get("dim").and_then(|x| x.as_usize()).unwrap_or(0),
            }),
            "score" => Ok(Response::Score {
                id: v.get("id").and_then(|x| x.as_u64()),
                score: v.get("score").and_then(|x| x.as_f64()).ok_or("score: missing score")?,
                features_evaluated: v
                    .get("features_evaluated")
                    .and_then(|x| x.as_usize())
                    .ok_or("score: missing features_evaluated")?,
                degraded: v.get("degraded").and_then(|b| b.as_bool()).unwrap_or(false),
            }),
            "classify" => {
                let id = v.get("id").and_then(|x| x.as_u64());
                let degraded =
                    v.get("degraded").and_then(|b| b.as_bool()).unwrap_or(false);
                let label =
                    v.get("label").and_then(|x| x.as_i64()).ok_or("classify: missing label")?;
                let votes = v.get("votes").and_then(|x| x.as_u64()).unwrap_or(0) as u32;
                let voters = v.get("voters").and_then(|x| x.as_u64()).unwrap_or(0) as u32;
                let features_evaluated = v
                    .get("features_evaluated")
                    .and_then(|x| x.as_usize())
                    .ok_or("classify: missing features_evaluated")?;
                match v.get("per_voter").and_then(|a| a.as_arr()) {
                    None => Ok(Response::Classify {
                        id,
                        label,
                        votes,
                        voters,
                        features_evaluated,
                        degraded,
                    }),
                    Some(rows) => Ok(Response::ClassifyVerbose {
                        id,
                        label,
                        votes,
                        voters,
                        features_evaluated,
                        degraded,
                        per_voter: rows
                            .iter()
                            .map(|row| {
                                Ok(VoterVote {
                                    pos: row
                                        .get("pos")
                                        .and_then(|x| x.as_i64())
                                        .ok_or("per_voter: missing pos")?,
                                    neg: row
                                        .get("neg")
                                        .and_then(|x| x.as_i64())
                                        .ok_or("per_voter: missing neg")?,
                                    vote: row
                                        .get("vote")
                                        .and_then(|x| x.as_i64())
                                        .ok_or("per_voter: missing vote")?,
                                    features: row
                                        .get("features")
                                        .and_then(|x| x.as_u64())
                                        .unwrap_or(0)
                                        as u32,
                                })
                            })
                            .collect::<Result<_, String>>()?,
                    }),
                }
            }
            "learn" => Ok(Response::Learned {
                id: v.get("id").and_then(|x| x.as_u64()),
                gen: v.get("gen").and_then(|x| x.as_u64()).ok_or("learn: missing gen")? as u32,
                seen: v.get("seen").and_then(|x| x.as_u64()).ok_or("learn: missing seen")?,
            }),
            "score-batch" => Ok(Response::ScoreBatch {
                id: v.get("id").and_then(|x| x.as_u64()),
                degraded: v.get("degraded").and_then(|b| b.as_bool()).unwrap_or(false),
                results: v
                    .get("results")
                    .and_then(|a| a.as_arr())
                    .ok_or("score-batch: missing results")?
                    .iter()
                    .map(|row| {
                        if let Some(e) = row.get("error").and_then(|s| s.as_str()) {
                            return Ok(BatchRow::err(e));
                        }
                        Ok(BatchRow::ok(
                            row.get("score")
                                .and_then(|x| x.as_f64())
                                .ok_or("score-batch: missing score")?,
                            row.get("features_evaluated")
                                .and_then(|x| x.as_usize())
                                .ok_or("score-batch: missing features_evaluated")?,
                        ))
                    })
                    .collect::<Result<_, String>>()?,
            }),
            "stats" => Ok(Response::Stats(StatsReport::from_json(&v))),
            "models" => Ok(Response::Models(
                v.get("models")
                    .and_then(|a| a.as_arr())
                    .ok_or("models: missing models")?
                    .iter()
                    .map(ModelEntry::from_json)
                    .collect::<Result<_, _>>()?,
            )),
            "reload" => Ok(Response::Reloaded {
                dim: v.get("dim").and_then(|x| x.as_usize()).ok_or("reload: missing dim")?,
            }),
            "add-model" => Ok(Response::Added {
                name: v
                    .get("name")
                    .and_then(|s| s.as_str())
                    .ok_or("add-model: missing name")?
                    .to_string(),
                id: v.get("id").and_then(|x| x.as_u64()).ok_or("add-model: missing id")? as u16,
                dim: v.get("dim").and_then(|x| x.as_usize()).unwrap_or(0),
            }),
            "remove-model" => Ok(Response::Removed {
                name: v
                    .get("name")
                    .and_then(|s| s.as_str())
                    .ok_or("remove-model: missing name")?
                    .to_string(),
            }),
            "pong" => Ok(Response::Pong),
            other => Err(format!("unknown response op {other:?}")),
        }
    }

    /// Is this the `overloaded` shed response?
    pub fn is_overloaded(&self) -> bool {
        matches!(self, Response::Error { error, retryable: true, .. } if error == "overloaded")
    }

    /// Is this the protocol-v7 `deadline-exceeded` shed response (the
    /// request's deadline passed while it queued, so the server dropped
    /// it unscored)? Matches both the bare wire code name and the
    /// server's descriptive message form.
    pub fn is_deadline_exceeded(&self) -> bool {
        matches!(
            self,
            Response::Error { error, retryable: true, .. }
                if error == "deadline-exceeded" || error.starts_with("deadline exceeded")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::ModelSnapshot;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    #[test]
    fn score_request_round_trip() {
        let req = Request::Score {
            id: Some(9),
            model: None,
            features: Features::Dense(vec![0.0, -1.5, 0.25]),
            deadline_ms: None,
            priority: None,
        };
        let line = req.to_line();
        assert!(line.ends_with('\n'));
        assert!(!line.contains("\"model\""), "unrouted requests omit the model field");
        assert!(!line.contains("deadline_ms"), "no deadline means no field on the wire");
        assert!(!line.contains("priority"), "no lane override means no field on the wire");
        match Request::parse(line.trim()).unwrap() {
            Request::Score { id, model, features: Features::Dense(features), .. } => {
                assert_eq!(id, Some(9));
                assert_eq!(model, None);
                assert_eq!(features, vec![0.0, -1.5, 0.25]);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // Without an id.
        let req = Request::Score {
            id: None,
            model: None,
            features: Features::Dense(vec![1.0]),
            deadline_ms: None,
            priority: None,
        };
        match Request::parse(&req.to_line()).unwrap() {
            Request::Score { id, .. } => assert_eq!(id, None),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn routed_score_and_classify_round_trip() {
        let req = Request::Score {
            id: None,
            model: Some("digits-2v3".into()),
            features: Features::Dense(vec![1.0]),
            deadline_ms: None,
            priority: None,
        };
        match Request::parse(&req.to_line()).unwrap() {
            Request::Score { model, .. } => assert_eq!(model.as_deref(), Some("digits-2v3")),
            other => panic!("wrong variant {other:?}"),
        }
        let req = Request::Classify {
            id: Some(3),
            model: Some("digits".into()),
            features: Features::Sparse { idx: vec![5, 9], val: vec![1.0, -1.0] },
            verbose: false,
            deadline_ms: None,
            priority: None,
        };
        let line = req.to_line();
        assert!(!line.contains("verbose"), "non-verbose requests omit the flag");
        match Request::parse(&line).unwrap() {
            Request::Classify {
                id,
                model,
                features: Features::Sparse { idx, .. },
                verbose,
                ..
            } => {
                assert_eq!(id, Some(3));
                assert_eq!(model.as_deref(), Some("digits"));
                assert_eq!(idx, vec![5, 9]);
                assert!(!verbose);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // Classify payloads get the same structural screening as score.
        assert!(Request::parse(r#"{"op":"classify"}"#).is_err(), "missing features");
        assert!(
            Request::parse(r#"{"op":"classify","idx":[5,2],"val":[1.0,2.0]}"#).is_err(),
            "unsorted idx"
        );
    }

    #[test]
    fn verbose_classify_round_trips() {
        // Request: the flag survives the round trip.
        let req = Request::Classify {
            id: None,
            model: Some("digits".into()),
            features: Features::Sparse { idx: vec![5], val: vec![1.0] },
            verbose: true,
            deadline_ms: None,
            priority: None,
        };
        let line = req.to_line();
        assert!(line.contains("\"verbose\":true"));
        match Request::parse(&line).unwrap() {
            Request::Classify { verbose, .. } => assert!(verbose),
            other => panic!("wrong variant {other:?}"),
        }
        // Verbose on a score is a parse error, not a silent drop.
        assert!(Request::parse(r#"{"op":"score","verbose":true,"features":[1.0]}"#).is_err());
        // Response: breakdown rows round-trip through the JSON form.
        let resp = Response::ClassifyVerbose {
            id: Some(4),
            label: 2,
            votes: 2,
            voters: 3,
            features_evaluated: 120,
            degraded: false,
            per_voter: vec![
                VoterVote { pos: 1, neg: 2, vote: 2, features: 40 },
                VoterVote { pos: 1, neg: 3, vote: 1, features: 50 },
                VoterVote { pos: 2, neg: 3, vote: 2, features: 30 },
            ],
        };
        match Response::parse(resp.to_line().trim()).unwrap() {
            Response::ClassifyVerbose { id, label, features_evaluated, per_voter, .. } => {
                assert_eq!(id, Some(4));
                assert_eq!(label, 2);
                assert_eq!(features_evaluated, 120);
                assert_eq!(per_voter.len(), 3);
                assert_eq!(per_voter[1], VoterVote { pos: 1, neg: 3, vote: 1, features: 50 });
            }
            other => panic!("wrong variant {other:?}"),
        }
        // A plain classify response still parses as the lean variant.
        let lean = Response::Classify {
            id: None,
            label: 1,
            votes: 2,
            voters: 3,
            features_evaluated: 9,
            degraded: false,
        };
        assert!(matches!(
            Response::parse(lean.to_line().trim()).unwrap(),
            Response::Classify { .. }
        ));
    }

    #[test]
    fn classify_response_round_trips() {
        let resp = Response::Classify {
            id: Some(11),
            label: 7,
            votes: 9,
            voters: 45,
            features_evaluated: 1210,
            degraded: false,
        };
        match Response::parse(resp.to_line().trim()).unwrap() {
            Response::Classify { id, label, votes, voters, features_evaluated, .. } => {
                assert_eq!(id, Some(11));
                assert_eq!(label, 7);
                assert_eq!(votes, 9);
                assert_eq!(voters, 45);
                assert_eq!(features_evaluated, 1210);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn score_batch_round_trips_without_poisoning() {
        let req = Request::ScoreBatch {
            id: Some(7),
            model: Some("digits-2v3".into()),
            examples: vec![
                Features::Sparse { idx: vec![3, 17], val: vec![0.5, -1.2] },
                Features::Dense(vec![1.0, 0.0]),
                Features::Sparse { idx: vec![], val: vec![] },
            ],
            deadline_ms: None,
            priority: None,
        };
        let line = req.to_line();
        assert!(line.contains("\"op\":\"score-batch\""));
        match Request::parse(line.trim()).unwrap() {
            Request::ScoreBatch { id, model, examples, .. } => {
                assert_eq!(id, Some(7));
                assert_eq!(model.as_deref(), Some("digits-2v3"));
                assert_eq!(examples.len(), 3);
                assert!(matches!(&examples[1], Features::Dense(x) if x == &vec![1.0, 0.0]));
            }
            other => panic!("wrong variant {other:?}"),
        }
        // A structurally damaged example still parses: validation is
        // deferred to admission, where it degrades to that example's
        // own error row instead of failing the batch.
        match Request::parse(
            r#"{"op":"score-batch","examples":[{"idx":[5,2],"val":[1.0,2.0]}]}"#,
        )
        .unwrap()
        {
            Request::ScoreBatch { examples, .. } => assert_eq!(examples.len(), 1),
            other => panic!("wrong variant {other:?}"),
        }
        // Malformed JSON structure still fails the whole line.
        assert!(Request::parse(r#"{"op":"score-batch"}"#).is_err(), "missing examples");
        assert!(
            Request::parse(r#"{"op":"score-batch","examples":[{"idx":[1]}]}"#).is_err(),
            "idx without val"
        );

        let resp = Response::ScoreBatch {
            id: Some(7),
            results: vec![
                BatchRow::ok(1.25, 34),
                BatchRow::err("dimension-mismatch"),
                BatchRow::ok(-0.5, 9),
            ],
            degraded: false,
        };
        let line = resp.to_line();
        assert!(line.contains("\"error\":\"dimension-mismatch\""));
        match Response::parse(line.trim()).unwrap() {
            Response::ScoreBatch { id, results, .. } => {
                assert_eq!(id, Some(7));
                assert_eq!(results.len(), 3);
                assert_eq!(results[0], BatchRow::ok(1.25, 34));
                assert_eq!(results[1], BatchRow::err("dimension-mismatch"));
                assert_eq!(results[2], BatchRow::ok(-0.5, 9));
            }
            other => panic!("wrong variant {other:?}"),
        }
        // An empty batch round-trips too.
        let resp = Response::ScoreBatch { id: None, results: vec![], degraded: false };
        match Response::parse(resp.to_line().trim()).unwrap() {
            Response::ScoreBatch { id: None, results, .. } => assert!(results.is_empty()),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn models_op_round_trips() {
        assert!(matches!(
            Request::parse(&Request::Models.to_line()).unwrap(),
            Request::Models
        ));
        let entries = vec![
            ModelEntry {
                name: "default".into(),
                id: 0,
                kind: "binary".into(),
                gen: 1,
                dim: 784,
                voters: 0,
                learn: true,
                state: "serving".into(),
            },
            ModelEntry {
                name: "digits".into(),
                id: 1,
                kind: "ensemble".into(),
                gen: 3,
                dim: 784,
                voters: 45,
                learn: false,
                state: "draining".into(),
            },
        ];
        match Response::parse(&Response::Models(entries.clone()).to_line()).unwrap() {
            Response::Models(back) => assert_eq!(back, entries),
            other => panic!("wrong variant {other:?}"),
        }
        // Pre-v5 rows carry no state; they parse as serving.
        match Response::parse(
            r#"{"ok":true,"op":"models","models":[{"name":"default","id":0}]}"#,
        )
        .unwrap()
        {
            Response::Models(back) => assert_eq!(back[0].state, "serving"),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn add_and_remove_model_round_trip() {
        let snapshot = ModelSnapshot {
            weights: vec![0.5, -1.0],
            var_sn: 2.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        };
        let req = Request::AddModel {
            name: "pair-4v9".into(),
            snapshot: snapshot.clone().into(),
            learn: true,
        };
        let line = req.to_line();
        assert!(line.contains("\"op\":\"add-model\"") && line.contains("\"learn\":true"));
        match Request::parse(line.trim()).unwrap() {
            Request::AddModel { name, snapshot: ServingModel::Binary(back), learn } => {
                assert_eq!(name, "pair-4v9");
                assert_eq!(back.weights, snapshot.weights);
                assert!(learn);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // The learn flag is optional and defaults off.
        let req = Request::AddModel {
            name: "pair-4v9".into(),
            snapshot: snapshot.into(),
            learn: false,
        };
        let line = req.to_line();
        assert!(!line.contains("learn"), "non-learn adds omit the flag");
        match Request::parse(line.trim()).unwrap() {
            Request::AddModel { learn: false, .. } => {}
            other => panic!("wrong variant {other:?}"),
        }
        assert!(Request::parse(r#"{"op":"add-model","name":"x"}"#).is_err(), "missing snapshot");
        assert!(
            Request::parse(r#"{"op":"add-model","snapshot":{}}"#).is_err(),
            "missing name"
        );

        let req = Request::RemoveModel { name: "pair-4v9".into() };
        match Request::parse(&req.to_line()).unwrap() {
            Request::RemoveModel { name } => assert_eq!(name, "pair-4v9"),
            other => panic!("wrong variant {other:?}"),
        }
        assert!(Request::parse(r#"{"op":"remove-model"}"#).is_err(), "missing name");

        let resp = Response::Added { name: "pair-4v9".into(), id: 3, dim: 784 };
        match Response::parse(resp.to_line().trim()).unwrap() {
            Response::Added { name, id, dim } => {
                assert_eq!((name.as_str(), id, dim), ("pair-4v9", 3, 784));
            }
            other => panic!("wrong variant {other:?}"),
        }
        let resp = Response::Removed { name: "pair-4v9".into() };
        match Response::parse(resp.to_line().trim()).unwrap() {
            Response::Removed { name } => assert_eq!(name, "pair-4v9"),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn learn_request_round_trips_and_validates_label() {
        let req = Request::Learn {
            id: Some(12),
            model: Some("digits-2v3".into()),
            label: -1,
            features: Features::Sparse { idx: vec![3, 17], val: vec![0.5, -1.2] },
        };
        let line = req.to_line();
        assert!(line.contains("\"op\":\"learn\"") && line.contains("\"y\":-1"));
        match Request::parse(line.trim()).unwrap() {
            Request::Learn { id, model, label, features: Features::Sparse { idx, val } } => {
                assert_eq!(id, Some(12));
                assert_eq!(model.as_deref(), Some("digits-2v3"));
                assert_eq!(label, -1);
                assert_eq!(idx, vec![3, 17]);
                assert_eq!(val, vec![0.5, -1.2]);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // Dense form, default shard, positive label.
        let req = Request::Learn {
            id: None,
            model: None,
            label: 1,
            features: Features::Dense(vec![0.0, 1.0]),
        };
        match Request::parse(&req.to_line()).unwrap() {
            Request::Learn { model: None, label: 1, .. } => {}
            other => panic!("wrong variant {other:?}"),
        }
        // The label is mandatory and must be exactly ±1.
        assert!(Request::parse(r#"{"op":"learn","features":[1.0]}"#).is_err(), "missing y");
        assert!(Request::parse(r#"{"op":"learn","y":0,"features":[1.0]}"#).is_err(), "y=0");
        assert!(Request::parse(r#"{"op":"learn","y":2,"features":[1.0]}"#).is_err(), "y=2");
        // Learn payloads get the same structural screening as score.
        assert!(
            Request::parse(r#"{"op":"learn","y":1,"idx":[5,2],"val":[1.0,2.0]}"#).is_err(),
            "unsorted idx"
        );
        assert!(
            Request::parse(r#"{"op":"learn","y":1,"idx":[1],"val":[1e999]}"#).is_err(),
            "non-finite value"
        );
        assert!(
            Request::parse(r#"{"op":"learn","y":1,"verbose":true,"features":[1.0]}"#).is_err(),
            "verbose is classify-only"
        );
    }

    #[test]
    fn learn_response_round_trips() {
        let resp = Response::Learned { id: Some(12), gen: 7, seen: 4096 };
        match Response::parse(resp.to_line().trim()).unwrap() {
            Response::Learned { id, gen, seen } => {
                assert_eq!(id, Some(12));
                assert_eq!(gen, 7);
                assert_eq!(seen, 4096);
            }
            other => panic!("wrong variant {other:?}"),
        }
        match Response::parse(&Response::Learned { id: None, gen: 0, seen: 1 }.to_line())
            .unwrap()
        {
            Response::Learned { id: None, gen: 0, seen: 1 } => {}
            other => panic!("wrong variant {other:?}"),
        }
        assert!(Response::parse(r#"{"ok":true,"op":"learn","gen":1}"#).is_err(), "missing seen");
    }

    #[test]
    fn sparse_score_request_round_trip() {
        let req = Request::Score {
            id: Some(4),
            model: None,
            features: Features::Sparse { idx: vec![3, 17, 40], val: vec![0.5, -1.2, 2.0] },
            deadline_ms: None,
            priority: None,
        };
        let line = req.to_line();
        assert!(line.contains("\"idx\"") && line.contains("\"val\""));
        assert!(!line.contains("\"features\""));
        match Request::parse(line.trim()).unwrap() {
            Request::Score { id, features: Features::Sparse { idx, val }, .. } => {
                assert_eq!(id, Some(4));
                assert_eq!(idx, vec![3, 17, 40]);
                assert_eq!(val, vec![0.5, -1.2, 2.0]);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn v7_admission_fields_round_trip() {
        // deadline_ms and priority survive the round trip on all three
        // scoring ops.
        let req = Request::Score {
            id: Some(1),
            model: None,
            features: Features::Dense(vec![1.0]),
            deadline_ms: Some(250),
            priority: Some(Lane::Bulk),
        };
        let line = req.to_line();
        assert!(line.contains("\"deadline_ms\":250"));
        assert!(line.contains("\"priority\":\"bulk\""));
        match Request::parse(line.trim()).unwrap() {
            Request::Score { deadline_ms, priority, .. } => {
                assert_eq!(deadline_ms, Some(250));
                assert_eq!(priority, Some(Lane::Bulk));
            }
            other => panic!("wrong variant {other:?}"),
        }
        let req = Request::Classify {
            id: None,
            model: Some("digits".into()),
            features: Features::Dense(vec![1.0]),
            verbose: false,
            deadline_ms: Some(5),
            priority: None,
        };
        match Request::parse(&req.to_line()).unwrap() {
            Request::Classify { deadline_ms: Some(5), priority: None, .. } => {}
            other => panic!("wrong variant {other:?}"),
        }
        let req = Request::ScoreBatch {
            id: None,
            model: None,
            examples: vec![Features::Dense(vec![1.0])],
            deadline_ms: None,
            priority: Some(Lane::Interactive),
        };
        let line = req.to_line();
        assert!(line.contains("\"priority\":\"interactive\""));
        match Request::parse(line.trim()).unwrap() {
            Request::ScoreBatch { deadline_ms: None, priority, .. } => {
                assert_eq!(priority, Some(Lane::Interactive));
            }
            other => panic!("wrong variant {other:?}"),
        }
        // Malformed admission fields are structured parse errors.
        assert!(
            Request::parse(r#"{"op":"score","features":[1.0],"priority":"turbo"}"#).is_err(),
            "unknown lane name"
        );
        assert!(
            Request::parse(r#"{"op":"score","features":[1.0],"deadline_ms":-5}"#).is_err(),
            "negative deadline"
        );
        assert!(
            Request::parse(r#"{"op":"learn","y":1,"features":[1.0],"deadline_ms":9}"#)
                .is_err(),
            "deadlines are scoring-only"
        );
        assert!(
            Request::parse(r#"{"op":"learn","y":1,"features":[1.0],"priority":"bulk"}"#)
                .is_err(),
            "lane overrides are scoring-only"
        );
    }

    #[test]
    fn degraded_flag_round_trips_and_is_omitted_when_false() {
        let resp = Response::Score {
            id: None,
            score: 0.5,
            features_evaluated: 12,
            degraded: true,
        };
        let line = resp.to_line();
        assert!(line.contains("\"degraded\":true"));
        match Response::parse(line.trim()).unwrap() {
            Response::Score { degraded, .. } => assert!(degraded),
            other => panic!("wrong variant {other:?}"),
        }
        // A normal-tier response carries no flag at all, so pre-v7
        // byte-level captures are unchanged.
        let resp =
            Response::Score { id: None, score: 0.5, features_evaluated: 12, degraded: false };
        assert!(!resp.to_line().contains("degraded"));
        let resp = Response::ScoreBatch {
            id: Some(2),
            results: vec![BatchRow::ok(1.0, 3)],
            degraded: true,
        };
        match Response::parse(resp.to_line().trim()).unwrap() {
            Response::ScoreBatch { degraded, .. } => assert!(degraded),
            other => panic!("wrong variant {other:?}"),
        }
        let resp = Response::Classify {
            id: None,
            label: 1,
            votes: 2,
            voters: 3,
            features_evaluated: 9,
            degraded: true,
        };
        match Response::parse(resp.to_line().trim()).unwrap() {
            Response::Classify { degraded, .. } => assert!(degraded),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn sparse_score_request_rejects_malformed_forms() {
        let parse = Request::parse;
        assert!(parse(r#"{"op":"score","idx":[1,2]}"#).is_err(), "idx without val");
        assert!(parse(r#"{"op":"score","val":[1.0]}"#).is_err(), "val without idx");
        assert!(
            parse(r#"{"op":"score","features":[1],"idx":[0],"val":[1]}"#).is_err(),
            "dense and sparse together"
        );
        assert!(
            parse(r#"{"op":"score","idx":[1],"val":[1.0,2.0]}"#).is_err(),
            "length mismatch"
        );
        assert!(
            parse(r#"{"op":"score","idx":[5,2],"val":[1.0,2.0]}"#).is_err(),
            "unsorted idx"
        );
        assert!(
            parse(r#"{"op":"score","idx":[2,2],"val":[1.0,2.0]}"#).is_err(),
            "duplicate idx"
        );
        assert!(parse(r#"{"op":"score","idx":[-1],"val":[1.0]}"#).is_err(), "negative idx");
        assert!(parse(r#"{"op":"score","idx":[1.5],"val":[1.0]}"#).is_err(), "fractional idx");
        assert!(
            parse(r#"{"op":"score","idx":[1],"val":[1e999]}"#).is_err(),
            "non-finite sparse value must be rejected with a structured error"
        );
        // The empty support is valid (scores 0.0 immediately).
        assert!(parse(r#"{"op":"score","idx":[],"val":[]}"#).is_ok());
    }

    #[test]
    fn hello_round_trips_and_defaults_to_v1() {
        match Request::parse(&Request::Hello { proto: 2 }.to_line()).unwrap() {
            Request::Hello { proto } => assert_eq!(proto, 2),
            other => panic!("wrong variant {other:?}"),
        }
        match Request::parse(r#"{"op":"hello"}"#).unwrap() {
            Request::Hello { proto } => assert_eq!(proto, 1, "missing proto means v1"),
            other => panic!("wrong variant {other:?}"),
        }
        let resp = Response::Hello { proto: 2, gen: 5, dim: 784 };
        match Response::parse(&resp.to_line()).unwrap() {
            Response::Hello { proto, gen, dim } => {
                assert_eq!((proto, gen, dim), (2, 5, 784));
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn control_requests_round_trip() {
        assert!(matches!(Request::parse(&Request::Stats.to_line()).unwrap(), Request::Stats));
        assert!(matches!(Request::parse(&Request::Ping.to_line()).unwrap(), Request::Ping));
        let snapshot = ModelSnapshot {
            weights: vec![1.0, -2.0],
            var_sn: 3.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        };
        let req = Request::Reload {
            model: Some("pair-a".into()),
            snapshot: snapshot.clone().into(),
        };
        match Request::parse(&req.to_line()).unwrap() {
            Request::Reload { model, snapshot: ServingModel::Binary(back) } => {
                assert_eq!(model.as_deref(), Some("pair-a"));
                assert_eq!(back.weights, snapshot.weights);
                assert_eq!(back.boundary, snapshot.boundary);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // Unrouted reload (v1 compat) parses with no model.
        let req = Request::Reload { model: None, snapshot: snapshot.into() };
        match Request::parse(&req.to_line()).unwrap() {
            Request::Reload { model: None, .. } => {}
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn request_parse_rejects_malformed_lines() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("{}").is_err(), "missing op");
        assert!(Request::parse(r#"{"op":"warp"}"#).is_err(), "unknown op");
        assert!(Request::parse(r#"{"op":"score"}"#).is_err(), "missing features");
        assert!(
            Request::parse(r#"{"op":"score","features":[1,"x"]}"#).is_err(),
            "non-numeric feature"
        );
        assert!(
            Request::parse(r#"{"op":"score","features":[1,1e999]}"#).is_err(),
            "non-finite feature must be rejected before it can poison a response"
        );
        assert!(Request::parse(r#"{"op":"reload"}"#).is_err(), "missing snapshot");
    }

    #[test]
    fn response_round_trips() {
        let r = Response::Score {
            id: Some(3),
            score: -0.75,
            features_evaluated: 41,
            degraded: false,
        };
        match Response::parse(r.to_line().trim()).unwrap() {
            Response::Score { id, score, features_evaluated, .. } => {
                assert_eq!(id, Some(3));
                assert_eq!(score, -0.75);
                assert_eq!(features_evaluated, 41);
            }
            other => panic!("wrong variant {other:?}"),
        }
        match Response::parse(&Response::Reloaded { dim: 784 }.to_line()).unwrap() {
            Response::Reloaded { dim } => assert_eq!(dim, 784),
            other => panic!("wrong variant {other:?}"),
        }
        assert!(matches!(Response::parse(&Response::Pong.to_line()).unwrap(), Response::Pong));
    }

    #[test]
    fn stats_report_round_trip() {
        let report = StatsReport {
            served: 1000,
            avg_features: 93.5,
            early_exit_rate: 0.875,
            batches: 120,
            features_p50: 63,
            features_p90: 511,
            features_p99: 1023,
            accepted_conns: 5,
            overloaded: 17,
            batch_shed: 3,
            worker_panics: 1,
            deadline_sheds: 9,
            degraded_responses: 40,
            brownout_tier: 2,
            tier_transitions: 6,
            protocol_errors: 2,
            reloads: 1,
            uptime_s: 4.5,
            req_per_s: 222.2,
            wire_v1: WireStats { served: 600, bytes: 48_000 },
            wire_v2_json: WireStats { served: 100, bytes: 9_000 },
            wire_v2_binary: WireStats { served: 300, bytes: 7_500 },
            models: vec![
                ModelStatsReport {
                    name: "default".into(),
                    state: "serving".into(),
                    served: 700,
                    avg_features: 80.0,
                    early_exit_rate: 0.9,
                    gen: 2,
                    reloads: 1,
                    trainer: true,
                    learn_examples: 5_000,
                    learn_updates: 1_200,
                    learn_sheds: 3,
                    learn_publishes: 19,
                    learn_features: 88_000,
                },
                ModelStatsReport {
                    name: "digits".into(),
                    state: "draining".into(),
                    served: 300,
                    avg_features: 400.0,
                    early_exit_rate: 0.8,
                    gen: 1,
                    reloads: 0,
                    trainer: false,
                    learn_examples: 0,
                    learn_updates: 0,
                    learn_sheds: 0,
                    learn_publishes: 0,
                    learn_features: 0,
                },
            ],
        };
        match Response::parse(&Response::Stats(report.clone()).to_line()).unwrap() {
            Response::Stats(back) => assert_eq!(back, report),
            other => panic!("wrong variant {other:?}"),
        }
        // A pre-registry report (no wire/models keys) parses with empty
        // defaults, so old servers stay readable.
        match Response::parse(
            r#"{"ok":true,"op":"stats","served":5,"req_per_s":1.0}"#,
        )
        .unwrap()
        {
            Response::Stats(back) => {
                assert_eq!(back.served, 5);
                assert_eq!(back.wire_v1, WireStats::default());
                assert!(back.models.is_empty());
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn error_responses_flag_retryability() {
        let shed = Response::Error { id: None, error: "overloaded".into(), retryable: true };
        let parsed = Response::parse(&shed.to_line()).unwrap();
        assert!(parsed.is_overloaded());
        let fatal =
            Response::Error { id: Some(1), error: "dimension mismatch".into(), retryable: false };
        match Response::parse(&fatal.to_line()).unwrap() {
            Response::Error { id, error, retryable } => {
                assert_eq!(id, Some(1));
                assert!(error.contains("dimension"));
                assert!(!retryable);
            }
            other => panic!("wrong variant {other:?}"),
        }
        assert!(!Response::parse(&fatal.to_line()).unwrap().is_overloaded());
    }
}
