//! Fault-injection points for chaos testing the serving stack.
//!
//! A fault point is a named place in the server where a test (or an
//! operator reproducing an incident) can force a failure: a torn
//! response write, a delayed flush, a worker panic mid-evaluation, or a
//! snapshot file left truncated as if the process died mid-write. The
//! points are **zero-cost when off**: every check is a single relaxed
//! atomic load of a global mask that is zero unless a test (or the
//! `ATTENTIVE_FAULT` environment variable at `serve` startup) armed
//! something — no branches into parsing, no allocation, nothing on the
//! steady-state hot path beyond the one load.
//!
//! Spec grammar (env var or [`configure`] argument):
//!
//! ```text
//! ATTENTIVE_FAULT=point:n[:arg][,point:n[:arg]...]
//! ```
//!
//! where `point` is one of `torn-write`, `delay`, `worker-panic`,
//! `snapshot-fail`; `n` means "fire on every n-th traversal" (n = 1
//! fires always, n = 0 disarms); and `arg` is the point-specific
//! parameter (`delay` only: milliseconds to sleep). Firing is
//! deterministic — a per-point traversal counter, not a coin flip — so
//! chaos runs reproduce.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// A named fault-injection site. The discriminant doubles as the bit
/// position in the armed mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Point {
    /// Write only a prefix of a response flush, then drop the
    /// connection — the client sees a torn frame and must reconnect.
    TornWrite = 0,
    /// Sleep before flushing a response (the `arg` is milliseconds) —
    /// exercises client deadlines without touching the server's answer.
    Delay = 1,
    /// Panic inside worker evaluation — exercises `catch_unwind`
    /// containment and the structured retryable `internal` error.
    WorkerPanic = 2,
    /// Leave the snapshot file truncated mid-payload instead of
    /// completing the atomic write — exercises startup recovery's
    /// checksum screen.
    SnapshotFail = 3,
}

const POINTS: usize = 4;

/// Bit `i` set = point with discriminant `i` is armed. The single load
/// every traversal pays when everything is off.
static ARMED: AtomicU32 = AtomicU32::new(0);
/// Fire on every `period`-th traversal (0 = disarmed).
static PERIOD: [AtomicU64; POINTS] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
/// Traversals since arming, per point.
static HITS: [AtomicU64; POINTS] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
/// Point-specific argument (`delay`: milliseconds).
static ARG: [AtomicU64; POINTS] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
/// Times each point actually fired (observable by tests).
static FIRED: [AtomicU64; POINTS] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

impl Point {
    fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "torn-write" => Ok(Point::TornWrite),
            "delay" => Ok(Point::Delay),
            "worker-panic" => Ok(Point::WorkerPanic),
            "snapshot-fail" => Ok(Point::SnapshotFail),
            other => Err(format!(
                "unknown fault point {other:?} (torn-write | delay | worker-panic | snapshot-fail)"
            )),
        }
    }
}

/// Should this traversal of `point` inject its fault? One relaxed load
/// when nothing is armed; deterministic every-n-th firing when armed.
#[inline]
pub fn fires(point: Point) -> bool {
    if ARMED.load(Ordering::Relaxed) == 0 {
        return false;
    }
    fires_armed(point)
}

#[cold]
fn fires_armed(point: Point) -> bool {
    let i = point as usize;
    let period = PERIOD[i].load(Ordering::Relaxed);
    if period == 0 {
        return false;
    }
    let hit = HITS[i].fetch_add(1, Ordering::Relaxed) + 1;
    let firing = hit % period == 0;
    if firing {
        FIRED[i].fetch_add(1, Ordering::Relaxed);
    }
    firing
}

/// The armed argument for `point` (`delay`: milliseconds). 0 when unset.
pub fn arg(point: Point) -> u64 {
    ARG[point as usize].load(Ordering::Relaxed)
}

/// Times `point` has actually fired since the last [`configure`].
pub fn fired(point: Point) -> u64 {
    FIRED[point as usize].load(Ordering::Relaxed)
}

/// If the `delay` point fires, sleep its configured milliseconds.
/// Call sites use this instead of pairing [`fires`] with a manual
/// sleep so the delay semantics stay in one place.
#[inline]
pub fn maybe_delay() {
    if fires(Point::Delay) {
        std::thread::sleep(std::time::Duration::from_millis(arg(Point::Delay).min(60_000)));
    }
}

/// If the `worker-panic` point fires, panic (contained by the worker's
/// `catch_unwind`).
#[inline]
pub fn maybe_panic() {
    if fires(Point::WorkerPanic) {
        panic!("injected fault: worker-panic");
    }
}

/// Disarm every point and zero the counters.
pub fn reset() {
    ARMED.store(0, Ordering::Relaxed);
    for i in 0..POINTS {
        PERIOD[i].store(0, Ordering::Relaxed);
        HITS[i].store(0, Ordering::Relaxed);
        ARG[i].store(0, Ordering::Relaxed);
        FIRED[i].store(0, Ordering::Relaxed);
    }
}

/// Arm fault points from a spec string (see the module docs for the
/// grammar). An empty spec disarms everything. Errors leave the
/// previous arming untouched.
pub fn configure(spec: &str) -> Result<(), String> {
    let spec = spec.trim();
    let mut arming: Vec<(Point, u64, u64)> = Vec::new();
    if !spec.is_empty() {
        for part in spec.split(',') {
            let mut it = part.trim().split(':');
            let name = it.next().unwrap_or("");
            let point = Point::from_name(name)?;
            let period: u64 = it
                .next()
                .ok_or_else(|| format!("fault point {name}: missing period (point:n[:arg])"))?
                .parse()
                .map_err(|_| format!("fault point {name}: period must be an integer"))?;
            let arg: u64 = match it.next() {
                Some(a) => a
                    .parse()
                    .map_err(|_| format!("fault point {name}: arg must be an integer"))?,
                None => 0,
            };
            if it.next().is_some() {
                return Err(format!("fault point {name}: too many fields (point:n[:arg])"));
            }
            arming.push((point, period, arg));
        }
    }
    reset();
    let mut mask = 0u32;
    for (point, period, arg) in arming {
        let i = point as usize;
        PERIOD[i].store(period, Ordering::Relaxed);
        ARG[i].store(arg, Ordering::Relaxed);
        if period != 0 {
            mask |= 1 << i;
        }
    }
    ARMED.store(mask, Ordering::Relaxed);
    Ok(())
}

/// Arm from `ATTENTIVE_FAULT` if set (called once at `serve` startup).
/// Returns the armed spec for the startup banner, `None` when unset.
///
/// # Panics
///
/// On an unparseable spec: the variable exists to force faults in a
/// chaos run, and a typo silently running a healthy server would make
/// that run vacuous.
pub fn init_from_env() -> Option<String> {
    match std::env::var("ATTENTIVE_FAULT") {
        Ok(spec) => {
            configure(&spec).unwrap_or_else(|e| panic!("ATTENTIVE_FAULT: {e}"));
            Some(spec)
        }
        Err(_) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// The arming state is process-global; tests serialize on this.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn off_by_default_and_after_reset() {
        let _g = LOCK.lock().unwrap();
        reset();
        assert!(!fires(Point::TornWrite));
        assert!(!fires(Point::WorkerPanic));
        assert_eq!(fired(Point::TornWrite), 0);
    }

    #[test]
    fn every_nth_firing_is_deterministic() {
        let _g = LOCK.lock().unwrap();
        configure("torn-write:3").unwrap();
        let pattern: Vec<bool> = (0..9).map(|_| fires(Point::TornWrite)).collect();
        assert_eq!(
            pattern,
            [false, false, true, false, false, true, false, false, true]
        );
        assert_eq!(fired(Point::TornWrite), 3);
        // Unarmed points in the same config stay silent.
        assert!(!fires(Point::WorkerPanic));
        reset();
    }

    #[test]
    fn spec_parses_args_and_rejects_garbage() {
        let _g = LOCK.lock().unwrap();
        configure("delay:1:250,worker-panic:5").unwrap();
        assert_eq!(arg(Point::Delay), 250);
        assert!(fires(Point::Delay));
        assert!(configure("coin-flip:1").is_err());
        assert!(configure("delay").is_err());
        assert!(configure("delay:x").is_err());
        assert!(configure("delay:1:2:3").is_err());
        // A failed configure leaves the previous arming in place.
        assert_eq!(arg(Point::Delay), 250);
        // Empty spec disarms.
        configure("").unwrap();
        assert!(!fires(Point::Delay));
    }

    #[test]
    fn period_zero_disarms_a_point() {
        let _g = LOCK.lock().unwrap();
        configure("torn-write:0,delay:2").unwrap();
        assert!(!fires(Point::TornWrite));
        assert!(!fires(Point::Delay));
        assert!(fires(Point::Delay));
        reset();
    }
}
