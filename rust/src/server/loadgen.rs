//! Load-generator client for the TCP serving front-end.
//!
//! Two layers:
//!
//! * [`Client`] — a synchronous request/response connection, used as the
//!   control channel (ping / stats / models / reload / add-model /
//!   remove-model) and for one-off scoring or classification. Starts in
//!   v1 JSON-lines mode; [`Client::negotiate`] upgrades it to the
//!   binary framing at the highest version the server grants (v7 down
//!   to v2) with transparent fallback on old servers. On a v7
//!   connection [`Client::score_sparse_ex`] / [`Client::score_batch_ex`]
//!   stamp a per-request deadline and admission lane on the wire, and
//!   [`Client::batcher`] wraps [`Client::score_batch`] in a windowed
//!   size-or-time batcher.
//!   [`Client::call_retry`] adds the resilient shape: jittered
//!   exponential backoff on `retryable` server errors, and
//!   reconnect-plus-renegotiate when the transport dies under a
//!   request.
//! * [`run`] — the load generator proper: `connections` client threads
//!   drive the server over loopback (or any address) with a configurable
//!   pipelining window, an easy/hard traffic mix — clean synthetic
//!   digits exit early, heavily-noised ones force deep evaluations — and
//!   a selectable [`ClientMode`] (v1 dense JSON, v2 sparse JSON, v2
//!   binary frames, v6 batched `SCORE_BATCH` frames, or binary
//!   multiclass `classify`). Requests can be
//!   routed to a named registry shard (`LoadGenConfig.model`). The
//!   merged [`LoadReport`] carries per-request features-touched counts
//!   for exact percentile reporting plus wire byte totals for
//!   cost-per-request comparisons (and voter totals for classify runs).
//!   `LoadGenConfig.open_loop` flips the driver into **open-loop**
//!   shape: a few worker threads hold `connections` sockets open
//!   (thousands, mostly idle at any instant) and sweep one
//!   request-response at a time across them — the scaling check for
//!   the event-loop transport backend. `LoadGenConfig.retries` arms
//!   the closed-loop drivers' fault recovery: a connection that dies
//!   mid-run is reopened (re-handshaking) and its unanswered window
//!   re-sent, tallied under `LoadReport.retries` / `reconnects`.
//!
//! The request hot path is allocation-free at steady state: digits
//! render into reusable buffers ([`SynthDigits::render_into`]),
//! sparsification reuses its index/value vectors, and requests encode
//! straight from those slices ([`Frame::put_score_sparse`] /
//! [`Frame::put_sparse_v3`] / a direct JSON writer) — so benchmark CPU
//! measures the server and the wire, not the generator.
//!
//! Traffic is 784-dimensional digit imagery (the paper's MNIST shape);
//! point it at a server that serves a 784-dim model.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::coordinator::service::{Features, ModelSnapshot, ServingModel};
use crate::data::synth::{SynthConfig, SynthDigits};
use crate::error::{Error, Result};
use crate::server::frame::{
    BatchResult, ErrorCode, Frame, FrameError, BATCH_STATUS_OK, FLAG_DEGRADED, LANE_DEFAULT,
};
use crate::server::protocol::{
    ModelEntry, Request, Response, StatsReport, PROTO_V2, PROTO_V3, PROTO_V4, PROTO_V5, PROTO_V6,
    PROTO_V7,
};
use crate::util::rng::Rng64;

/// Frame-length cap the client applies to server responses.
const CLIENT_MAX_FRAME: usize = 1 << 20;

/// Counts raw bytes pulled off a socket (sits under the `BufReader`).
struct CountingReader<R> {
    inner: R,
    bytes: u64,
}

impl<R> CountingReader<R> {
    fn new(inner: R) -> Self {
        Self { inner, bytes: 0 }
    }
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.bytes += n as u64;
        Ok(n)
    }
}

/// Retry shape for [`Client::call_retry`]: exponential backoff from
/// `base_backoff_ms` doubling per attempt, capped at `max_backoff_ms`,
/// with jitter drawn uniformly from the upper half of the window so
/// simultaneous retriers decorrelate instead of stampeding.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Re-send attempts after the first try (0 = fail fast).
    pub max_retries: u32,
    /// Backoff before the first retry, in milliseconds.
    pub base_backoff_ms: u64,
    /// Backoff ceiling, in milliseconds.
    pub max_backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_retries: 3, base_backoff_ms: 10, max_backoff_ms: 1_000 }
    }
}

/// Jittered exponential backoff for retry `attempt` (1-based): double
/// the base per attempt, cap, then draw from the upper half of the
/// window.
fn retry_backoff(rng: &mut Rng64, policy: &RetryPolicy, attempt: u32) -> std::time::Duration {
    let exp = policy.base_backoff_ms.saturating_mul(1u64 << (attempt.saturating_sub(1)).min(16));
    let cap = exp.min(policy.max_backoff_ms).max(1);
    let ms = cap / 2 + rng.next_u64() % (cap / 2 + 1);
    std::time::Duration::from_millis(ms)
}

/// A synchronous client connection (v1 JSON lines until negotiated up).
pub struct Client {
    addr: String,
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    proto: u32,
    /// Whether [`Self::negotiate`] ran on this connection — replayed by
    /// [`Self::reconnect`] so a re-opened stream comes back at the same
    /// protocol level the caller negotiated.
    negotiated: bool,
    /// Requests re-sent by [`Self::call_retry`].
    retries: u64,
    /// Fresh connections opened after a transport fault.
    reconnects: u64,
    /// Backoff jitter source (seeded from the address, not the clock,
    /// so runs stay reproducible).
    rng: Rng64,
}

impl Client {
    /// Connect to a serving front-end (v1 JSON-lines mode).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).map_err(|e| Error::io(addr, e))?;
        let read_half = stream.try_clone().map_err(|e| Error::io(addr, e))?;
        let seed = addr
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3));
        Ok(Client {
            addr: addr.to_string(),
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
            proto: 1,
            negotiated: false,
            retries: 0,
            reconnects: 0,
            rng: Rng64::seed_from_u64(seed),
        })
    }

    /// The protocol version this connection currently speaks.
    pub fn proto(&self) -> u32 {
        self.proto
    }

    /// Requests [`Self::call_retry`] has re-sent on this client.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Connections [`Self::reconnect`] has re-opened on this client.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Tear down the (presumed dead) connection and open a fresh one to
    /// the same address, replaying the protocol negotiation if this
    /// client had negotiated binary framing. Any in-flight request on
    /// the old connection is abandoned — callers re-send.
    pub fn reconnect(&mut self) -> Result<()> {
        let fresh = Client::connect(&self.addr)?;
        self.reader = fresh.reader;
        self.writer = fresh.writer;
        self.proto = 1;
        self.reconnects += 1;
        if self.negotiated {
            self.negotiate()?;
        }
        Ok(())
    }

    /// Send one request with retries: a transport fault (reset,
    /// truncated frame, server restart) reconnects — re-running the
    /// handshake — and re-sends; a server error marked `retryable`
    /// (shed, internal panic, model-busy) backs off and re-sends on the
    /// same connection. Backoff is exponential with jitter (see
    /// [`RetryPolicy`]). Returns the final response (possibly still a
    /// retryable error) once the budget is spent, or the final
    /// transport error if a reconnect fails.
    ///
    /// Scoring and control ops are idempotent and safe here. A `learn`
    /// whose ack is lost to a transport fault may already be applied —
    /// re-sending double-counts the example, which online training
    /// tolerates but exactly-once accounting does not.
    pub fn call_retry(&mut self, req: &Request, policy: &RetryPolicy) -> Result<Response> {
        let mut attempt = 0u32;
        loop {
            match self.call(req) {
                Ok(Response::Error { retryable: true, .. }) if attempt < policy.max_retries => {
                    attempt += 1;
                    self.retries += 1;
                    let pause = retry_backoff(&mut self.rng, policy, attempt);
                    std::thread::sleep(pause);
                }
                Ok(resp) => return Ok(resp),
                Err(_) if attempt < policy.max_retries => {
                    attempt += 1;
                    self.retries += 1;
                    let pause = retry_backoff(&mut self.rng, policy, attempt);
                    std::thread::sleep(pause);
                    // A reconnect that itself fails (e.g. the fault tore
                    // the fresh handshake too) spends budget and tries
                    // again rather than giving up mid-policy.
                    while let Err(e) = self.reconnect() {
                        if attempt >= policy.max_retries {
                            return Err(e);
                        }
                        attempt += 1;
                        self.retries += 1;
                        let pause = retry_backoff(&mut self.rng, policy, attempt);
                        std::thread::sleep(pause);
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Negotiate binary framing, asking for the highest version this
    /// build speaks (v7). Returns the granted version: 7 down to 2 on
    /// success (all switch to binary frames; 3 unlocks the model-routed
    /// frame ops, 4 the online-learning `LEARN_SPARSE` frame, 5 the
    /// runtime `add-model` / `remove-model` shard lifecycle ops, 6
    /// the batched `SCORE_BATCH` scoring frame, and 7 the deadline- and
    /// lane-carrying `SCORE_SPARSE_EX` / `SCORE_BATCH_EX` frames), 1
    /// when the server declines or predates the handshake (transparent
    /// fallback — the connection keeps working in JSON-lines mode
    /// either way).
    pub fn negotiate(&mut self) -> Result<u32> {
        if self.proto >= PROTO_V2 {
            return Ok(self.proto);
        }
        self.negotiated = true;
        let line = Request::Hello { proto: PROTO_V7 }.to_line();
        self.writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.flush())
            .map_err(|e| Error::io("<client write>", e))?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(|e| Error::io("<client read>", e))?;
        if n == 0 {
            return Err(Error::format("hello reply", "connection closed"));
        }
        match Response::parse(reply.trim()).map_err(|e| Error::format("hello reply", e))? {
            Response::Hello { proto, .. } if proto >= PROTO_V2 => {
                self.proto = proto.min(PROTO_V7);
                Ok(self.proto)
            }
            // Declined (proto 1) or a pre-handshake server answering
            // "unknown op": stay on JSON lines.
            Response::Hello { .. } | Response::Error { .. } => Ok(1),
            other => Err(Error::format("hello reply", format!("unexpected {other:?}"))),
        }
    }

    /// Read one binary frame and lift it into the JSON response type.
    fn read_frame_response(&mut self) -> Result<Response> {
        match Frame::read_from(&mut self.reader, CLIENT_MAX_FRAME) {
            Err(e) => Err(Error::format("server frame", e.to_string())),
            Ok(Frame::JsonResp(doc)) => {
                Response::parse(doc.trim()).map_err(|e| Error::format("server reply", e))
            }
            Ok(Frame::Score { score, evaluated, .. }) => Ok(Response::Score {
                id: None,
                score,
                features_evaluated: evaluated as usize,
                degraded: false,
            }),
            Ok(Frame::ScoreEx { score, evaluated, flags, .. }) => Ok(Response::Score {
                id: None,
                score,
                features_evaluated: evaluated as usize,
                degraded: flags & FLAG_DEGRADED != 0,
            }),
            Ok(Frame::Class { label, votes, voters, evaluated, .. }) => Ok(Response::Classify {
                id: None,
                label,
                votes,
                voters,
                features_evaluated: evaluated as usize,
                degraded: false,
            }),
            Ok(Frame::ClassVerbose { label, votes, voters, evaluated, per_voter, .. }) => {
                Ok(Response::ClassifyVerbose {
                    id: None,
                    label,
                    votes,
                    voters,
                    features_evaluated: evaluated as usize,
                    per_voter,
                    degraded: false,
                })
            }
            Ok(Frame::LearnAck { gen, seen }) => Ok(Response::Learned { id: None, gen, seen }),
            Ok(Frame::Error { code, retryable, msg }) => Ok(Response::Error {
                id: None,
                error: if msg.is_empty() { code.name().to_string() } else { msg },
                retryable,
            }),
            Ok(other) => {
                Err(Error::format("server frame", format!("unexpected frame {other:?}")))
            }
        }
    }

    /// Send one pre-encoded binary frame and wait for its response.
    fn call_frame(&mut self, frame: Frame) -> Result<Response> {
        self.writer
            .write_all(&frame.encode())
            .and_then(|()| self.writer.flush())
            .map_err(|e| Error::io("<client write>", e))?;
        self.read_frame_response()
    }

    /// Ensure the connection granted at least `needed` (call
    /// [`Self::negotiate`] first for 2+).
    fn require_proto(&self, needed: u32, what: &str) -> Result<()> {
        if self.proto < needed {
            return Err(Error::format(
                what,
                format!("needs protocol v{needed}, connection speaks v{}", self.proto),
            ));
        }
        Ok(())
    }

    /// Send one request and wait for its response (on a v2 connection
    /// the request rides a `JSON_REQ` envelope frame).
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        if self.proto >= PROTO_V2 {
            let frame = Frame::JsonReq(req.to_json().to_string_compact()).encode();
            self.writer
                .write_all(&frame)
                .and_then(|()| self.writer.flush())
                .map_err(|e| Error::io("<client write>", e))?;
            return self.read_frame_response();
        }
        let line = req.to_line();
        self.writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.flush())
            .map_err(|e| Error::io("<client write>", e))?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(|e| Error::io("<client read>", e))?;
        if n == 0 {
            return Err(Error::format("server reply", "connection closed"));
        }
        Response::parse(reply.trim()).map_err(|e| Error::format("server reply", e))
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(Error::format("ping reply", format!("unexpected {other:?}"))),
        }
    }

    /// Score one dense feature vector (on the default shard).
    pub fn score(&mut self, features: Vec<f64>) -> Result<Response> {
        self.call(&Request::Score {
            id: None,
            model: None,
            features: Features::Dense(features),
            deadline_ms: None,
            priority: None,
        })
    }

    /// Score one payload on a named registry shard (JSON routing; works
    /// on any protocol version).
    pub fn score_model(&mut self, model: &str, features: impl Into<Features>) -> Result<Response> {
        self.call(&Request::Score {
            id: None,
            model: Some(model.to_string()),
            features: features.into(),
            deadline_ms: None,
            priority: None,
        })
    }

    /// Score one sparse payload on the default shard. On a binary
    /// connection this is a native `SCORE_SPARSE` frame (`gen` pins a
    /// model generation, 0 = any); on v1 it falls back to the sparse
    /// JSON form — which cannot carry a pin, so a nonzero `gen` on a v1
    /// connection is an error rather than a silently dropped guarantee.
    pub fn score_sparse(&mut self, idx: Vec<u32>, val: Vec<f64>, gen: u32) -> Result<Response> {
        if self.proto < PROTO_V2 && gen != 0 {
            return Err(Error::format(
                "score_sparse",
                "generation pinning needs protocol v2 (call negotiate() first)",
            ));
        }
        if self.proto >= PROTO_V2 {
            let idx16: Vec<u16> = idx
                .iter()
                .map(|&i| u16::try_from(i))
                .collect::<std::result::Result<_, _>>()
                .map_err(|_| Error::format("score_sparse", "idx exceeds the u16 wire bound"))?;
            return self.call_frame(Frame::ScoreSparse { gen, idx: idx16, val });
        }
        self.call(&Request::Score {
            id: None,
            model: None,
            features: Features::Sparse { idx, val },
            deadline_ms: None,
            priority: None,
        })
    }

    /// Score one sparse payload on shard `model` with the v3 frame
    /// (`u32` indices — dims beyond 65536 fit). Needs a negotiated v3
    /// connection.
    pub fn score_sparse2(
        &mut self,
        model: u16,
        idx: Vec<u32>,
        val: Vec<f64>,
        gen: u32,
    ) -> Result<Response> {
        self.require_proto(PROTO_V3, "score_sparse2")?;
        self.call_frame(Frame::ScoreSparse2 { model, gen, idx, val })
    }

    /// Score one dense payload on shard `model` with the v3 binary
    /// frame. Needs a negotiated v3 connection.
    pub fn score_dense_binary(
        &mut self,
        model: u16,
        val: Vec<f64>,
        gen: u32,
    ) -> Result<Response> {
        self.require_proto(PROTO_V3, "score_dense_binary")?;
        self.call_frame(Frame::ScoreDense { model, gen, val })
    }

    /// Classify one payload (attentive all-pairs vote) on a named
    /// ensemble shard via the JSON op (works on any protocol version;
    /// `None` routes to the default shard).
    pub fn classify(
        &mut self,
        model: Option<&str>,
        features: impl Into<Features>,
    ) -> Result<Response> {
        self.call(&Request::Classify {
            id: None,
            model: model.map(str::to_string),
            features: features.into(),
            verbose: false,
            deadline_ms: None,
            priority: None,
        })
    }

    /// [`Self::classify`] asking for the per-voter cost breakdown
    /// (`"verbose":true` → a response carrying one row per 1-vs-1
    /// voter). Works on any protocol version.
    pub fn classify_verbose(
        &mut self,
        model: Option<&str>,
        features: impl Into<Features>,
    ) -> Result<Response> {
        self.call(&Request::Classify {
            id: None,
            model: model.map(str::to_string),
            features: features.into(),
            verbose: true,
            deadline_ms: None,
            priority: None,
        })
    }

    /// Classify one sparse payload on shard `model` with the native v3
    /// binary frame. Needs a negotiated v3 connection.
    pub fn classify_sparse(
        &mut self,
        model: u16,
        idx: Vec<u32>,
        val: Vec<f64>,
        gen: u32,
    ) -> Result<Response> {
        self.require_proto(PROTO_V3, "classify_sparse")?;
        self.call_frame(Frame::ClassifySparse { model, gen, idx, val })
    }

    /// [`Self::classify_sparse`] with the per-voter breakdown: sends
    /// `CLASSIFY_SPARSE_VERBOSE` (`0x06`), answered by `CLASS_VERBOSE`
    /// (`0x85`). Needs a negotiated v3 connection.
    pub fn classify_sparse_verbose(
        &mut self,
        model: u16,
        idx: Vec<u32>,
        val: Vec<f64>,
        gen: u32,
    ) -> Result<Response> {
        self.require_proto(PROTO_V3, "classify_sparse_verbose")?;
        self.call_frame(Frame::ClassifySparseVerbose { model, gen, idx, val })
    }

    /// Feed one labeled example to a shard's online trainer via the
    /// JSON `learn` op (works on any protocol version; `None` routes to
    /// the default shard). The `Learned` response carries the shard's
    /// current serving generation and the trainer's cumulative
    /// accepted-example count.
    pub fn learn(
        &mut self,
        model: Option<&str>,
        label: i8,
        features: impl Into<Features>,
    ) -> Result<Response> {
        self.call(&Request::Learn {
            id: None,
            model: model.map(str::to_string),
            label,
            features: features.into(),
        })
    }

    /// Feed one labeled sparse example with the native v4 binary frame
    /// (`LEARN_SPARSE`, answered by `LEARN_ACK`). Needs a negotiated v4
    /// connection.
    pub fn learn_sparse(
        &mut self,
        model: u16,
        label: i8,
        idx: Vec<u32>,
        val: Vec<f64>,
    ) -> Result<Response> {
        self.require_proto(PROTO_V4, "learn_sparse")?;
        self.call_frame(Frame::LearnSparse { model, label, idx, val })
    }

    /// Score a batch of sparse examples on shard `model` with one v6
    /// `SCORE_BATCH` frame (`gen` pins a model generation, 0 = any).
    /// The whole batch costs one server queue slot and is scored
    /// back-to-back by one worker — bit-identical to sending the same
    /// examples singly. Answers one [`BatchResult`] row per example in
    /// submission order, each with its own status byte
    /// ([`BATCH_STATUS_OK`] or an [`ErrorCode`] wire byte), so one bad
    /// example never poisons its batchmates. Whole-batch failures
    /// (unknown model, stale pin, overload, an over-long batch) come
    /// back as a single error. Needs a negotiated v6 connection.
    pub fn score_batch(
        &mut self,
        model: u16,
        gen: u32,
        examples: &[(Vec<u32>, Vec<f64>)],
    ) -> Result<Vec<BatchResult>> {
        self.require_proto(PROTO_V6, "score_batch")?;
        let mut out = Vec::new();
        let mut enc = Frame::begin_score_batch(&mut out, model, gen);
        for (idx, val) in examples {
            enc.push_example(idx, val);
        }
        enc.finish();
        self.writer
            .write_all(&out)
            .and_then(|()| self.writer.flush())
            .map_err(|e| Error::io("<client write>", e))?;
        match Frame::read_from(&mut self.reader, CLIENT_MAX_FRAME) {
            Err(e) => Err(Error::format("server frame", e.to_string())),
            Ok(Frame::ScoreBatchResp { results, .. }) => Ok(results),
            Ok(Frame::Error { code, msg, .. }) => Err(Error::format(
                "score_batch",
                if msg.is_empty() { code.name().to_string() } else { msg },
            )),
            Ok(other) => {
                Err(Error::format("server frame", format!("unexpected frame {other:?}")))
            }
        }
    }

    /// Score a batch via the JSON `score-batch` op (the [`Self::score_batch`]
    /// twin for JSON-lines / envelope connections; works on any
    /// protocol version, `None` routes to the default shard). The
    /// response carries one row per example with a per-row `error`
    /// field instead of a status byte.
    pub fn score_batch_json(
        &mut self,
        model: Option<&str>,
        examples: Vec<Features>,
    ) -> Result<Response> {
        self.call(&Request::ScoreBatch {
            id: None,
            model: model.map(str::to_string),
            examples,
            deadline_ms: None,
            priority: None,
        })
    }

    /// Score one sparse payload on shard `model` with the v7
    /// `SCORE_SPARSE_EX` frame, stamping a relative deadline
    /// (`deadline_ms`, 0 = server default) and an admission lane byte
    /// ([`LANE_DEFAULT`] / `LANE_INTERACTIVE` / `LANE_BULK`). A request
    /// still queued when its deadline passes is answered with the
    /// retryable `deadline-exceeded` error instead of being scored; a
    /// response scored under a brownout tier comes back with
    /// `degraded: true`. Needs a negotiated v7 connection.
    pub fn score_sparse_ex(
        &mut self,
        model: u16,
        gen: u32,
        deadline_ms: u32,
        lane: u8,
        idx: &[u32],
        val: &[f64],
    ) -> Result<Response> {
        self.require_proto(PROTO_V7, "score_sparse_ex")?;
        let mut out = Vec::new();
        Frame::put_sparse_ex(&mut out, model, gen, deadline_ms, lane, idx, val);
        self.writer
            .write_all(&out)
            .and_then(|()| self.writer.flush())
            .map_err(|e| Error::io("<client write>", e))?;
        self.read_frame_response()
    }

    /// [`Self::score_batch`] with the v7 `SCORE_BATCH_EX` frame: the
    /// whole batch carries one relative deadline and one admission lane
    /// byte. Returns the per-example rows plus the batch's `degraded`
    /// flag (scored under a brownout tier). Needs a negotiated v7
    /// connection.
    pub fn score_batch_ex(
        &mut self,
        model: u16,
        gen: u32,
        deadline_ms: u32,
        lane: u8,
        examples: &[(Vec<u32>, Vec<f64>)],
    ) -> Result<(Vec<BatchResult>, bool)> {
        self.require_proto(PROTO_V7, "score_batch_ex")?;
        let mut out = Vec::new();
        let mut enc = Frame::begin_score_batch_ex(&mut out, model, gen, deadline_ms, lane);
        for (idx, val) in examples {
            enc.push_example(idx, val);
        }
        enc.finish();
        self.writer
            .write_all(&out)
            .and_then(|()| self.writer.flush())
            .map_err(|e| Error::io("<client write>", e))?;
        match Frame::read_from(&mut self.reader, CLIENT_MAX_FRAME) {
            Err(e) => Err(Error::format("server frame", e.to_string())),
            Ok(Frame::ScoreBatchRespEx { results, flags, .. }) => {
                Ok((results, flags & FLAG_DEGRADED != 0))
            }
            Ok(Frame::Error { code, msg, .. }) => Err(Error::format(
                "score_batch_ex",
                if msg.is_empty() { code.name().to_string() } else { msg },
            )),
            Ok(other) => {
                Err(Error::format("server frame", format!("unexpected frame {other:?}")))
            }
        }
    }

    /// Wrap this connection in a windowed batcher: buffered examples
    /// flush as one `SCORE_BATCH` frame when `k` have accumulated
    /// (count trigger) or `window_us` microseconds have passed since
    /// the oldest buffered example (time trigger), whichever comes
    /// first — amortizing the per-frame round-trip without letting a
    /// slow trickle sit unbatched forever. Needs a negotiated v6
    /// connection.
    pub fn batcher(
        &mut self,
        model: u16,
        gen: u32,
        k: usize,
        window_us: u64,
    ) -> Result<Batcher<'_>> {
        self.require_proto(PROTO_V6, "batcher")?;
        Ok(Batcher {
            client: self,
            model,
            gen,
            window: BatchWindow::new(k, window_us)?,
            pending: Vec::new(),
        })
    }

    /// Fetch server statistics.
    pub fn stats(&mut self) -> Result<StatsReport> {
        match self.call(&Request::Stats)? {
            Response::Stats(report) => Ok(report),
            other => Err(Error::format("stats reply", format!("unexpected {other:?}"))),
        }
    }

    /// Fetch the registry's shard table (name → wire id / kind / gen).
    pub fn models(&mut self) -> Result<Vec<ModelEntry>> {
        match self.call(&Request::Models)? {
            Response::Models(entries) => Ok(entries),
            other => Err(Error::format("models reply", format!("unexpected {other:?}"))),
        }
    }

    /// Hot-swap the default shard's model; returns the new
    /// dimensionality.
    pub fn reload(&mut self, snapshot: &ModelSnapshot) -> Result<usize> {
        self.reload_model(None, &snapshot.clone().into())
    }

    /// Hot-swap a named shard's model (`None` = the default shard);
    /// returns the new dimensionality.
    pub fn reload_model(&mut self, model: Option<&str>, snapshot: &ServingModel) -> Result<usize> {
        let req =
            Request::Reload { model: model.map(str::to_string), snapshot: snapshot.clone() };
        match self.call(&req)? {
            Response::Reloaded { dim } => Ok(dim),
            Response::Error { error, .. } => Err(Error::format("reload reply", error)),
            other => Err(Error::format("reload reply", format!("unexpected {other:?}"))),
        }
    }

    /// Register a new shard at runtime (the protocol v5 `add-model`
    /// op); returns the assigned wire id and the shard's
    /// dimensionality. With `learn` the server attaches an online
    /// trainer using its own `--learn` knobs, warm-started from
    /// `snapshot`.
    pub fn add_model(
        &mut self,
        name: &str,
        snapshot: &ServingModel,
        learn: bool,
    ) -> Result<(u16, usize)> {
        let req =
            Request::AddModel { name: name.to_string(), snapshot: snapshot.clone(), learn };
        match self.call(&req)? {
            Response::Added { id, dim, .. } => Ok((id, dim)),
            Response::Error { error, .. } => Err(Error::format("add-model reply", error)),
            other => Err(Error::format("add-model reply", format!("unexpected {other:?}"))),
        }
    }

    /// Retire a shard at runtime (the protocol v5 `remove-model` op).
    /// The server unroutes the shard before answering; the quiesce and
    /// drain finish in the background.
    pub fn remove_model(&mut self, name: &str) -> Result<()> {
        match self.call(&Request::RemoveModel { name: name.to_string() })? {
            Response::Removed { .. } => Ok(()),
            Response::Error { error, .. } => Err(Error::format("remove-model reply", error)),
            other => {
                Err(Error::format("remove-model reply", format!("unexpected {other:?}")))
            }
        }
    }
}

/// The size-or-time flush policy behind [`Batcher`]: flush when `k`
/// examples have accumulated, or when `window` has elapsed since the
/// oldest buffered example arrived. Kept free of any I/O so both
/// triggers are unit-testable without a server.
#[derive(Debug)]
struct BatchWindow {
    /// Count trigger: flush at this many buffered examples.
    k: usize,
    /// Time trigger: flush `window` after the oldest buffered example.
    window: std::time::Duration,
    /// Buffered examples.
    len: usize,
    /// Arrival time of the oldest buffered example (`None` when empty).
    oldest: Option<Instant>,
}

impl BatchWindow {
    fn new(k: usize, window_us: u64) -> Result<BatchWindow> {
        if k == 0 {
            return Err(Error::Config("batcher k must be >= 1".into()));
        }
        Ok(BatchWindow {
            k,
            window: std::time::Duration::from_micros(window_us),
            len: 0,
            oldest: None,
        })
    }

    /// Record one buffered example at time `now`; returns `true` when
    /// the batch should flush — the push filled it to `k`, or the
    /// window had already expired.
    fn note_push(&mut self, now: Instant) -> bool {
        self.oldest.get_or_insert(now);
        self.len += 1;
        self.len >= self.k || self.due(now)
    }

    /// Whether the time trigger has fired: examples are buffered and
    /// the oldest has waited at least the window.
    fn due(&self, now: Instant) -> bool {
        self.oldest.is_some_and(|t| now.duration_since(t) >= self.window)
    }

    /// Forget the buffered examples (they were flushed).
    fn reset(&mut self) {
        self.len = 0;
        self.oldest = None;
    }
}

/// A client-side windowed batcher (see [`Client::batcher`]): buffers
/// single sparse examples and flushes them as one `SCORE_BATCH` frame
/// at `k` examples or `window_us` microseconds, whichever trips first.
/// Between pushes, call [`Batcher::flush_if_due`] so a lull in arrivals
/// cannot park a short batch past its window.
pub struct Batcher<'c> {
    client: &'c mut Client,
    model: u16,
    gen: u32,
    window: BatchWindow,
    pending: Vec<(Vec<u32>, Vec<f64>)>,
}

impl Batcher<'_> {
    /// Examples currently buffered.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Whether the time trigger has fired (the oldest buffered example
    /// has waited at least the window).
    pub fn due(&self) -> bool {
        self.window.due(Instant::now())
    }

    /// Buffer one example. Flushes — returning the batch's rows — when
    /// this push fills the batch to `k` or the window has expired;
    /// otherwise buffers and returns `None`.
    pub fn push(
        &mut self,
        idx: Vec<u32>,
        val: Vec<f64>,
    ) -> Result<Option<Vec<BatchResult>>> {
        self.pending.push((idx, val));
        if self.window.note_push(Instant::now()) {
            return self.flush().map(Some);
        }
        Ok(None)
    }

    /// Flush only if the time trigger has fired — the poll hook for
    /// callers waiting between arrivals.
    pub fn flush_if_due(&mut self) -> Result<Option<Vec<BatchResult>>> {
        if self.due() { self.flush().map(Some) } else { Ok(None) }
    }

    /// Flush the buffered examples now, regardless of trigger state
    /// (end-of-stream drain). An empty buffer returns no rows without
    /// touching the wire.
    pub fn flush(&mut self) -> Result<Vec<BatchResult>> {
        if self.pending.is_empty() {
            return Ok(Vec::new());
        }
        self.window.reset();
        let batch = std::mem::take(&mut self.pending);
        self.client.score_batch(self.model, self.gen, &batch)
    }
}

/// Which wire the load generator drives the server over.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClientMode {
    /// v1 dense JSON lines (`{"op":"score","features":[...]}`).
    #[default]
    V1Dense,
    /// v2 sparse JSON form over JSON lines (`{"idx":[...],"val":[...]}`).
    V2SparseJson,
    /// v2 binary frames after a `hello` handshake (`SCORE_SPARSE`).
    V2Binary,
    /// v6 batched scoring: `SCORE_BATCH` frames packing
    /// `LoadGenConfig.batch_size` examples each, answered by one
    /// `SCORE_BATCH_RESP` row per example. Counts tally per *example*,
    /// so its `req_per_s` compares directly against `v2-binary`
    /// singles — that ratio is the batching speedup.
    Batch,
    /// v3 binary multiclass classify frames (`CLASSIFY_SPARSE`) against
    /// an ensemble shard (set `LoadGenConfig.model`).
    Classify,
    /// v4 binary online-learning frames (`LEARN_SPARSE`): every request
    /// feeds a labeled example to the target shard's trainer. Labels
    /// come from the generated digit — the pair's first digit is the
    /// positive class.
    Learn,
    /// Mixed online traffic: alternating `LEARN_SPARSE` and
    /// `SCORE_SPARSE2` frames on the same connection — the serving
    /// shape of the learn-while-scoring acceptance loop.
    Mixed,
}

impl ClientMode {
    /// The binary-score wire modes, for three-way transport sweeps and
    /// benches (classify targets a different shard kind and is driven
    /// separately).
    pub const ALL: [ClientMode; 3] =
        [ClientMode::V1Dense, ClientMode::V2SparseJson, ClientMode::V2Binary];

    /// Kebab-case name (CLI flag value and bench row label).
    pub fn name(self) -> &'static str {
        match self {
            ClientMode::V1Dense => "v1-dense",
            ClientMode::V2SparseJson => "v2-sparse-json",
            ClientMode::V2Binary => "v2-binary",
            ClientMode::Batch => "batch",
            ClientMode::Classify => "classify",
            ClientMode::Learn => "learn",
            ClientMode::Mixed => "mixed",
        }
    }

    /// Parse the kebab-case name.
    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "v1-dense" => Ok(ClientMode::V1Dense),
            "v2-sparse-json" => Ok(ClientMode::V2SparseJson),
            "v2-binary" => Ok(ClientMode::V2Binary),
            "batch" => Ok(ClientMode::Batch),
            "classify" => Ok(ClientMode::Classify),
            "learn" => Ok(ClientMode::Learn),
            "mixed" => Ok(ClientMode::Mixed),
            other => Err(format!("unknown client mode {other:?}")),
        }
    }
}

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Server address, e.g. `"127.0.0.1:7878"`.
    pub addr: String,
    /// Concurrent client connections.
    pub connections: usize,
    /// Total requests across all connections.
    pub requests: usize,
    /// In-flight requests per connection (pipelining window).
    pub pipeline: usize,
    /// Fraction of requests rendered with heavy noise (hard inputs that
    /// defeat the early exit); the rest are clean (easy).
    pub hard_fraction: f64,
    /// Wire mode (see [`ClientMode`]).
    pub mode: ClientMode,
    /// Sparsification threshold for the sparse modes: entries with
    /// `|v| <= eps` are dropped client-side. 0.05 lands synthetic digits
    /// near MNIST density (~150 of 784 nonzeros).
    pub sparse_eps: f64,
    /// Examples packed per `SCORE_BATCH` frame in batch mode (ignored
    /// by the single-request modes). Must stay within the server's
    /// `max_batch_examples` knob — an over-long batch is one
    /// whole-batch error, not a truncation.
    pub batch_size: usize,
    /// Registry shard to route to: JSON score modes carry it as the
    /// `"model"` field, classify resolves it to a wire id via the
    /// `models` op. `None` drives the default shard.
    pub model: Option<String>,
    /// Digit classes the traffic generator cycles through (classify
    /// runs should match the target ensemble's classes).
    pub digits: Vec<u8>,
    /// Base RNG seed (per-connection streams are derived from it).
    pub seed: u64,
    /// Open-loop mode: instead of one driver thread per connection
    /// pipelining hard, a handful of worker threads each hold a large
    /// slice of `connections` sockets open and rotate requests across
    /// them. With `pipeline == 1` each shard keeps one request in
    /// flight — most connections idle at any instant, the shape that
    /// demonstrates (and regression-tests) the event-loop backend
    /// holding thousands of mostly-idle sockets without shedding. With
    /// `pipeline > 1` every socket holds a window of that many
    /// requests in flight per sweep — the past-capacity shape the
    /// overload smoke drives (see [`run_open_loop`]).
    pub open_loop: bool,
    /// Shard churn alongside the main traffic: a dedicated control
    /// connection cycles `add-model` → routed score → `remove-model`
    /// this many times on throwaway shards while the configured load
    /// runs, exercising the registry's epoch-based route swap under
    /// fire. 0 (the default) disables churn. Needs a protocol v5
    /// server.
    pub churn_cycles: usize,
    /// Transport-fault retry budget per driver connection: when a
    /// socket dies mid-run (reset, truncated frame, server restart) the
    /// closed-loop drivers reconnect, re-run the handshake, and re-send
    /// the unanswered pipeline window, up to this many *consecutive*
    /// times — any successfully read response refreshes the budget, so
    /// a long run rides out periodic faults while a hard-down server
    /// still fails after this many attempts. 0 (the default) keeps the
    /// fail-fast shape the benchmarks measure. Retryable *responses*
    /// (shed, internal) are tallied, never re-sent — the load generator
    /// measures shedding rather than hiding it.
    pub retries: u32,
    /// Relative deadline stamped on every binary score request, in
    /// milliseconds: the `v2-binary` mode switches to `SCORE_SPARSE_EX`
    /// frames and batch mode to `SCORE_BATCH_EX` (both need a protocol
    /// v7 server). A request still queued past its deadline is answered
    /// with the retryable `deadline-exceeded` error, tallied under
    /// `LoadReport.deadline_sheds`. 0 (the default) keeps the legacy
    /// frames — the server may still apply its own
    /// `--deadline-default-ms`.
    pub deadline_ms: u32,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            connections: 4,
            requests: 1_000,
            pipeline: 8,
            hard_fraction: 0.5,
            mode: ClientMode::V1Dense,
            sparse_eps: 0.05,
            batch_size: 16,
            model: None,
            digits: vec![2, 3],
            seed: 0,
            open_loop: false,
            churn_cycles: 0,
            retries: 0,
            deadline_ms: 0,
        }
    }
}

/// Merged outcome of a load-generation run.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Requests written to the wire.
    pub sent: u64,
    /// Score responses received.
    pub answered: u64,
    /// Learn acknowledgements received (examples the trainer accepted).
    pub learned: u64,
    /// Explicit `overloaded` shed responses received.
    pub overloaded: u64,
    /// Other error responses (protocol, dimension, transport).
    pub errors: u64,
    /// Sum of features touched over answered requests.
    pub total_features: u64,
    /// Request bytes written to the wire (payload cost per mode).
    pub bytes_sent: u64,
    /// Response bytes read from the wire.
    pub bytes_recv: u64,
    /// Wall-clock seconds (max over connections).
    pub elapsed_s: f64,
    /// Features touched per answered request (for exact percentiles).
    pub features: Vec<u32>,
    /// Voters consulted, summed over answered classify requests (0 for
    /// score traffic); `total_features / total_voters` is the per-voter
    /// feature cost.
    pub total_voters: u64,
    /// Completed add→score→remove churn cycles (see
    /// `LoadGenConfig::churn_cycles`).
    pub churned: u64,
    /// Requests re-sent after a transport fault ate their response (see
    /// `LoadGenConfig::retries`); counted on the same scale as `sent`.
    pub retries: u64,
    /// Fresh connections opened mid-run to replace dead ones.
    pub reconnects: u64,
    /// Retryable `deadline-exceeded` sheds received: requests the
    /// server dropped unscored because their deadline passed while
    /// queued. Counted per example, like `answered`.
    pub deadline_sheds: u64,
    /// Answered requests flagged `degraded` (scored under a brownout
    /// tier with a tightened early-exit boundary).
    pub degraded: u64,
}

impl LoadReport {
    /// Mean features touched per answered request.
    pub fn avg_features(&self) -> f64 {
        if self.answered == 0 { 0.0 } else { self.total_features as f64 / self.answered as f64 }
    }

    /// Responses (answered + learned + shed) per second.
    pub fn req_per_s(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            0.0
        } else {
            (self.answered + self.learned + self.overloaded) as f64 / self.elapsed_s
        }
    }

    /// Exact `p`-th percentile (`p ∈ [0, 1]`) of features touched.
    pub fn feature_percentile(&self, p: f64) -> u32 {
        if self.features.is_empty() {
            return 0;
        }
        let mut sorted = self.features.clone();
        sorted.sort_unstable();
        let idx = (p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx]
    }

    /// Mean request bytes written per sent request.
    pub fn bytes_per_req(&self) -> f64 {
        if self.sent == 0 { 0.0 } else { self.bytes_sent as f64 / self.sent as f64 }
    }

    /// Mean features touched per voter consulted (classify runs; 0.0
    /// when no voter totals were collected).
    pub fn avg_features_per_voter(&self) -> f64 {
        if self.total_voters == 0 {
            0.0
        } else {
            self.total_features as f64 / self.total_voters as f64
        }
    }

    /// Fold another connection's report into this one.
    pub fn merge(&mut self, other: &LoadReport) {
        self.sent += other.sent;
        self.answered += other.answered;
        self.learned += other.learned;
        self.overloaded += other.overloaded;
        self.errors += other.errors;
        self.total_features += other.total_features;
        self.bytes_sent += other.bytes_sent;
        self.bytes_recv += other.bytes_recv;
        self.elapsed_s = self.elapsed_s.max(other.elapsed_s);
        self.features.extend_from_slice(&other.features);
        self.total_voters += other.total_voters;
        self.churned += other.churned;
        self.retries += other.retries;
        self.reconnects += other.reconnects;
        self.deadline_sheds += other.deadline_sheds;
        self.degraded += other.degraded;
    }
}

/// Machine-readable summary of named load-generation passes — the
/// payload of `BENCH_serve.json`, consumed by CI's bench-smoke gate.
/// When both a `v1-dense` and a `v2-binary` pass are present, the
/// top-level `ratio_v2_binary_vs_v1_dense` records the throughput
/// multiple the protocol-v2 work bought; a `batch` pass alongside
/// `v2-binary` adds `ratio_batch_vs_singles` (both passes count per
/// example, so the ratio is the batching speedup directly).
pub fn report_to_json(requests: usize, passes: &[(String, LoadReport)]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let mut modes = Vec::new();
    for (name, r) in passes {
        let mut fields = vec![
            ("req_per_s", Json::Num(r.req_per_s())),
            ("avg_features", Json::Num(r.avg_features())),
            ("features_p50", Json::Num(r.feature_percentile(0.50) as f64)),
            ("features_p90", Json::Num(r.feature_percentile(0.90) as f64)),
            ("features_p99", Json::Num(r.feature_percentile(0.99) as f64)),
            ("answered", Json::Num(r.answered as f64)),
            ("overloaded", Json::Num(r.overloaded as f64)),
            ("errors", Json::Num(r.errors as f64)),
            ("bytes_sent", Json::Num(r.bytes_sent as f64)),
            ("bytes_recv", Json::Num(r.bytes_recv as f64)),
            ("bytes_per_req", Json::Num(r.bytes_per_req())),
            ("elapsed_s", Json::Num(r.elapsed_s)),
        ];
        if r.total_voters > 0 {
            // Classify pass: per-voter attention accounting.
            fields.push(("voters", Json::Num(r.total_voters as f64)));
            fields.push(("avg_features_per_voter", Json::Num(r.avg_features_per_voter())));
        }
        if r.learned > 0 {
            // Learn pass: accepted-example throughput.
            fields.push(("learned", Json::Num(r.learned as f64)));
        }
        if r.churned > 0 {
            // Churn pass: add→score→remove cycles completed mid-load.
            fields.push(("churn_cycles", Json::Num(r.churned as f64)));
        }
        if r.retries > 0 || r.reconnects > 0 {
            // Fault-recovery pass: transport retries the drivers absorbed.
            fields.push(("retries", Json::Num(r.retries as f64)));
            fields.push(("reconnects", Json::Num(r.reconnects as f64)));
        }
        if r.deadline_sheds > 0 || r.degraded > 0 {
            // Overload pass: brownout degradation and deadline sheds.
            fields.push(("deadline_sheds", Json::Num(r.deadline_sheds as f64)));
            fields.push(("degraded", Json::Num(r.degraded as f64)));
        }
        modes.push((name.clone(), Json::obj(fields)))
    }
    let find = |mode: ClientMode| {
        passes.iter().find(|(name, _)| name == mode.name()).map(|(_, r)| r)
    };
    let mut pairs = vec![
        ("bench", Json::Str("serve_throughput".into())),
        ("requests", Json::Num(requests as f64)),
        ("modes", Json::Obj(modes.into_iter().collect())),
    ];
    let v1 = find(ClientMode::V1Dense);
    if let (Some(v1), Some(v2)) = (v1, find(ClientMode::V2Binary)) {
        if v1.req_per_s() > 0.0 {
            pairs.push((
                "ratio_v2_binary_vs_v1_dense",
                Json::Num(v2.req_per_s() / v1.req_per_s()),
            ));
        }
    }
    if let (Some(v1), Some(sj)) = (v1, find(ClientMode::V2SparseJson)) {
        if v1.req_per_s() > 0.0 {
            pairs.push((
                "ratio_v2_sparse_json_vs_v1_dense",
                Json::Num(sj.req_per_s() / v1.req_per_s()),
            ));
        }
    }
    if let (Some(single), Some(batch)) = (find(ClientMode::V2Binary), find(ClientMode::Batch)) {
        if single.req_per_s() > 0.0 {
            pairs.push((
                "ratio_batch_vs_singles",
                Json::Num(batch.req_per_s() / single.req_per_s()),
            ));
        }
    }
    Json::obj(pairs)
}

/// Renderer config for the hard (heavily-noised) traffic class.
fn hard_render_config() -> SynthConfig {
    SynthConfig { pixel_noise: 0.35, salt_prob: 0.2, jitter_px: 4.0, ..Default::default() }
}

/// Lowest protocol grant this run's frames need (a nonzero deadline
/// moves the score wires onto the v7 `*_EX` frames).
fn required_proto(cfg: &LoadGenConfig) -> u32 {
    if cfg.deadline_ms > 0 && matches!(cfg.mode, ClientMode::V2Binary | ClientMode::Batch) {
        return PROTO_V7;
    }
    match cfg.mode {
        ClientMode::Classify => PROTO_V3,
        ClientMode::Learn | ClientMode::Mixed => PROTO_V4,
        ClientMode::Batch => PROTO_V6,
        _ => PROTO_V2,
    }
}

/// Modes whose frames carry a wire model id (need a `models` lookup
/// when a named shard is configured).
fn routes_by_id(mode: ClientMode) -> bool {
    matches!(
        mode,
        ClientMode::Batch | ClientMode::Classify | ClientMode::Learn | ClientMode::Mixed
    )
}

/// Label for learn traffic: the configured digit cycle's first digit is
/// the positive class, everything else negative — the same 1-vs-1 task
/// shape the offline `Trainer` uses.
fn learn_label(cfg: &LoadGenConfig, seq: u64) -> i8 {
    let digit = cfg.digits[seq as usize % cfg.digits.len()];
    if digit == cfg.digits[0] {
        1
    } else {
        -1
    }
}

/// Drive the server with mixed easy/hard digit traffic and merge the
/// per-connection reports.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadReport> {
    if cfg.connections == 0 || cfg.pipeline == 0 {
        return Err(Error::Config("loadgen connections and pipeline must be >= 1".into()));
    }
    if cfg.digits.is_empty() {
        return Err(Error::Config("loadgen digits must not be empty".into()));
    }
    if cfg.mode == ClientMode::V2Binary && cfg.model.is_some() {
        return Err(Error::Config(
            "the legacy v2-binary frame cannot route models; use v2-sparse-json or classify"
                .into(),
        ));
    }
    if cfg.mode == ClientMode::Classify && cfg.model.is_none() {
        return Err(Error::Config(
            "classify mode needs a target ensemble shard: set LoadGenConfig.model \
             (bench-serve --model NAME)"
                .into(),
        ));
    }
    if cfg.mode == ClientMode::Batch {
        if cfg.batch_size == 0 {
            return Err(Error::Config("loadgen batch_size must be >= 1".into()));
        }
        if cfg.open_loop {
            return Err(Error::Config(
                "batch mode is closed-loop only (the open-loop driver sweeps one \
                 request per socket by design)"
                    .into(),
            ));
        }
    }
    let (main, churn) = std::thread::scope(|scope| {
        // Churn rides a dedicated control connection so its add/remove
        // round-trips never slot into the main traffic's pipelines.
        let churn = (cfg.churn_cycles > 0).then(|| scope.spawn(move || drive_churn(cfg)));
        let main = if cfg.open_loop { run_open_loop(cfg) } else { run_closed_loop(cfg) };
        (main, churn.map(|j| j.join().expect("loadgen churn thread panicked")))
    });
    let mut merged = main?;
    if let Some(churn) = churn {
        merged.merge(&churn?);
    }
    Ok(merged)
}

/// The default (closed-loop) driver: one pipelining thread per
/// connection.
fn run_closed_loop(cfg: &LoadGenConfig) -> Result<LoadReport> {
    let per_conn = cfg.requests / cfg.connections;
    let remainder = cfg.requests % cfg.connections;
    let reports = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..cfg.connections {
            let n = per_conn + usize::from(c < remainder);
            joins.push(scope.spawn(move || match cfg.mode {
                ClientMode::Batch => drive_batch_connection(cfg, c as u64, n),
                _ => drive_connection(cfg, c as u64, n),
            }));
        }
        joins.into_iter().map(|j| j.join().expect("loadgen thread panicked")).collect::<Vec<_>>()
    });
    let mut merged = LoadReport::default();
    for r in reports {
        merged.merge(&r?);
    }
    Ok(merged)
}

/// The churn sidecar: cycle `add-model` → routed score → `remove-model`
/// on throwaway shards while the main traffic runs. Each cycle uses a
/// fresh name — removal drains in the background, so reusing a name
/// immediately could legitimately answer the retryable `model-busy`.
fn drive_churn(cfg: &LoadGenConfig) -> Result<LoadReport> {
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;
    let mut report = LoadReport::default();
    let mut client = Client::connect(&cfg.addr)?;
    if client.negotiate()? < PROTO_V5 {
        return Err(Error::format(
            "loadgen churn",
            "shard churn needs a protocol v5 server (add-model/remove-model)",
        ));
    }
    let snapshot: ServingModel = ModelSnapshot {
        weights: vec![1.0; 784],
        var_sn: 1.0,
        boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
        policy: CoordinatePolicy::Sequential,
    }
    .into();
    for i in 0..cfg.churn_cycles {
        let name = format!("churn-{}-{i}", cfg.seed);
        if client.add_model(&name, &snapshot, false).is_err() {
            report.errors += 1;
            continue;
        }
        report.sent += 1;
        match client.score_model(&name, vec![1.0; 784]) {
            Ok(Response::Score { features_evaluated, .. }) => {
                report.answered += 1;
                report.total_features += features_evaluated as u64;
                report.features.push(features_evaluated as u32);
            }
            _ => report.errors += 1,
        }
        match client.remove_model(&name) {
            Ok(()) => report.churned += 1,
            Err(_) => report.errors += 1,
        }
    }
    Ok(report)
}

/// How many worker threads the open-loop driver multiplexes its
/// sockets over — deliberately tiny, so `--connections 2000` means two
/// thousand *sockets*, not two thousand client threads.
const OPEN_LOOP_SHARDS: usize = 8;

/// Tally one binary response frame into the report.
fn count_binary_response(report: &mut LoadReport, frame: &Frame) {
    // One tally per batch row: batch traffic counts on the same
    // per-example scale as the single-frame modes, so batch and
    // singles `req_per_s` compare directly.
    fn count_rows(report: &mut LoadReport, results: &[BatchResult], degraded: bool) {
        for r in results {
            if r.status == BATCH_STATUS_OK {
                report.answered += 1;
                report.total_features += r.evaluated as u64;
                report.features.push(r.evaluated);
                report.degraded += u64::from(degraded);
            } else if r.status == ErrorCode::Overloaded as u8 {
                report.overloaded += 1;
            } else if r.status == ErrorCode::DeadlineExceeded as u8 {
                report.deadline_sheds += 1;
            } else {
                report.errors += 1;
            }
        }
    }
    match frame {
        Frame::LearnAck { .. } => report.learned += 1,
        Frame::Score { evaluated, .. } => {
            report.answered += 1;
            report.total_features += *evaluated as u64;
            report.features.push(*evaluated);
        }
        Frame::ScoreEx { evaluated, flags, .. } => {
            report.answered += 1;
            report.total_features += *evaluated as u64;
            report.features.push(*evaluated);
            report.degraded += u64::from(flags & FLAG_DEGRADED != 0);
        }
        Frame::Class { evaluated, voters, .. }
        | Frame::ClassVerbose { evaluated, voters, .. } => {
            report.answered += 1;
            report.total_features += *evaluated as u64;
            report.features.push(*evaluated);
            report.total_voters += *voters as u64;
        }
        Frame::ScoreBatchResp { results, .. } => count_rows(report, results, false),
        Frame::ScoreBatchRespEx { results, flags, .. } => {
            count_rows(report, results, flags & FLAG_DEGRADED != 0)
        }
        Frame::Error { code: ErrorCode::Overloaded, .. } => report.overloaded += 1,
        Frame::Error { code: ErrorCode::DeadlineExceeded, .. } => report.deadline_sheds += 1,
        _ => report.errors += 1,
    }
}

/// Tally one JSON response line into the report.
fn count_json_response(report: &mut LoadReport, line: &str) {
    match Response::parse(line.trim()) {
        Ok(Response::Learned { .. }) => report.learned += 1,
        Ok(Response::Score { features_evaluated, degraded, .. }) => {
            report.answered += 1;
            report.total_features += features_evaluated as u64;
            report.features.push(features_evaluated as u32);
            report.degraded += u64::from(degraded);
        }
        Ok(
            Response::Classify { features_evaluated, voters, degraded, .. }
            | Response::ClassifyVerbose { features_evaluated, voters, degraded, .. },
        ) => {
            report.answered += 1;
            report.total_features += features_evaluated as u64;
            report.features.push(features_evaluated as u32);
            report.total_voters += voters as u64;
            report.degraded += u64::from(degraded);
        }
        Ok(resp) if resp.is_overloaded() => report.overloaded += 1,
        Ok(resp) if resp.is_deadline_exceeded() => report.deadline_sheds += 1,
        _ => report.errors += 1,
    }
}

/// Open-loop driver: a few worker shards, each holding a contiguous
/// slice of the `connections` sockets open and sweeping requests
/// across them. With `pipeline == 1` (the default) each shard keeps
/// one request in flight at a time — in-flight never exceeds
/// [`OPEN_LOOP_SHARDS`], nothing is shed against a sane queue, and
/// what this measures is the server *holding* thousands of mostly-idle
/// connections, which is exactly the event-loop backend's claim (the
/// thread backend would need two threads per socket just to sit
/// there). With `pipeline > 1` each sweep writes up to `pipeline`
/// requests to **every** socket before draining their responses, so
/// shard-wide in-flight reaches `sockets × pipeline` — the
/// past-capacity shape the overload smoke drives: the admission queue
/// genuinely fills, deadlines expire in it, and the brownout
/// controller sees sustained pressure.
fn run_open_loop(cfg: &LoadGenConfig) -> Result<LoadReport> {
    let shards = cfg.connections.min(OPEN_LOOP_SHARDS).max(1);
    // Connection c (globally) issues `base + (c < rem)` requests.
    let reports = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for s in 0..shards {
            // Contiguous connection ranges per shard.
            let c0 = cfg.connections * s / shards;
            let c1 = cfg.connections * (s + 1) / shards;
            joins.push(scope.spawn(move || drive_open_loop_shard(cfg, s as u64, c0, c1)));
        }
        joins.into_iter().map(|j| j.join().expect("loadgen thread panicked")).collect::<Vec<_>>()
    });
    let mut merged = LoadReport::default();
    for r in reports {
        merged.merge(&r?);
    }
    Ok(merged)
}

/// One open-loop shard: sockets `[c0, c1)`, swept round-robin.
fn drive_open_loop_shard(
    cfg: &LoadGenConfig,
    shard_id: u64,
    c0: usize,
    c1: usize,
) -> Result<LoadReport> {
    let mut report = LoadReport::default();
    if c0 >= c1 {
        return Ok(report);
    }
    let base = cfg.requests / cfg.connections;
    let rem = cfg.requests % cfg.connections;
    let binary = matches!(
        cfg.mode,
        ClientMode::V2Binary | ClientMode::Classify | ClientMode::Learn | ClientMode::Mixed
    );

    struct Sock {
        stream: TcpStream,
        reader: BufReader<CountingReader<TcpStream>>,
        remaining: usize,
    }

    // Open (and for binary modes, negotiate) every socket up front —
    // from here on they mostly sit idle.
    let mut model_id = 0u16;
    let mut socks = Vec::with_capacity(c1 - c0);
    let mut line = String::new();
    for c in c0..c1 {
        let stream = TcpStream::connect(&cfg.addr).map_err(|e| Error::io(&cfg.addr, e))?;
        let read_half = stream.try_clone().map_err(|e| Error::io(&cfg.addr, e))?;
        // Small read buffer: responses are tiny and there are
        // thousands of these.
        let mut reader = BufReader::with_capacity(1024, CountingReader::new(read_half));
        if binary {
            let needed = required_proto(cfg);
            let hello = Request::Hello { proto: PROTO_V7 }.to_line();
            (&stream)
                .write_all(hello.as_bytes())
                .map_err(|e| Error::io("<loadgen hello>", e))?;
            report.bytes_sent += hello.len() as u64;
            line.clear();
            let n =
                reader.read_line(&mut line).map_err(|e| Error::io("<loadgen hello>", e))?;
            if n == 0 {
                return Err(Error::format("loadgen hello", "connection closed"));
            }
            match Response::parse(line.trim()) {
                Ok(Response::Hello { proto, .. }) if proto >= needed => {}
                other => {
                    return Err(Error::format(
                        "loadgen hello",
                        format!("not granted v{needed}: {other:?}"),
                    ))
                }
            }
            // Resolve the routed shard id once per shard, on the
            // first negotiated socket.
            if routes_by_id(cfg.mode) && c == c0 {
                if let Some(name) = &cfg.model {
                    let req =
                        Frame::JsonReq(Request::Models.to_json().to_string_compact()).encode();
                    (&stream).write_all(&req).map_err(|e| Error::io("<loadgen models>", e))?;
                    report.bytes_sent += req.len() as u64;
                    let entries = match Frame::read_from(&mut reader, CLIENT_MAX_FRAME) {
                        Ok(Frame::JsonResp(doc)) => match Response::parse(doc.trim()) {
                            Ok(Response::Models(entries)) => entries,
                            other => {
                                return Err(Error::format(
                                    "loadgen models",
                                    format!("unexpected reply {other:?}"),
                                ))
                            }
                        },
                        other => {
                            return Err(Error::format(
                                "loadgen models",
                                format!("unexpected frame {other:?}"),
                            ))
                        }
                    };
                    model_id = entries
                        .iter()
                        .find(|e| &e.name == name)
                        .ok_or_else(|| {
                            Error::format("loadgen models", format!("no shard named {name:?}"))
                        })?
                        .id;
                }
            }
        }
        socks.push(Sock { stream, reader, remaining: base + usize::from(c < rem) });
    }

    let seed = cfg.seed.wrapping_add(shard_id.wrapping_mul(0xD1B5_4A32_D192_ED03));
    let mut clean = SynthDigits::new(seed);
    let mut noisy = SynthDigits::with_config(seed ^ 0xA5A5_A5A5, hard_render_config());
    let mut mix = Rng64::seed_from_u64(seed ^ 0x5A5A_5A5A);
    let mut dense = Vec::new();
    let mut scratch = EncodeScratch::default();
    let mut frame_body = Vec::new();
    let mut seq = 0u64;

    let t0 = Instant::now();
    if cfg.pipeline > 1 {
        // Windowed sweep: every socket gets up to `pipeline` requests
        // written before any response is read, so the shard holds
        // `sockets × pipeline` in flight — the past-capacity shape.
        // `remaining` counts down here (the legacy sweep below compares
        // it against the round index instead). Error accounting keeps
        // the `sent == answered + sheds + errors` invariant: a dead
        // read charges one error per undrained in-flight request.
        let mut burst = vec![0usize; socks.len()];
        loop {
            let mut live = false;
            for (sock, burst) in socks.iter_mut().zip(burst.iter_mut()) {
                *burst = 0;
                while sock.remaining > 0 && *burst < cfg.pipeline {
                    let digit = cfg.digits[seq as usize % cfg.digits.len()];
                    if mix.f64() < cfg.hard_fraction {
                        noisy.render_into(digit, &mut dense)
                    } else {
                        clean.render_into(digit, &mut dense)
                    };
                    encode_request_into(cfg, model_id, seq, &dense, &mut scratch);
                    seq += 1;
                    if (&sock.stream).write_all(&scratch.out).is_err() {
                        report.errors += 1;
                        sock.remaining = 0;
                        break;
                    }
                    report.bytes_sent += scratch.out.len() as u64;
                    report.sent += 1;
                    sock.remaining -= 1;
                    *burst += 1;
                }
                live |= *burst > 0 || sock.remaining > 0;
            }
            for (sock, burst) in socks.iter_mut().zip(burst.iter()) {
                for drained in 0..*burst {
                    let ok = if binary {
                        match Frame::read_body(
                            &mut sock.reader,
                            &mut frame_body,
                            CLIENT_MAX_FRAME,
                        )
                        .and_then(|()| Frame::decode_body(&frame_body))
                        {
                            Ok(frame) => {
                                count_binary_response(&mut report, &frame);
                                true
                            }
                            Err(_) => false,
                        }
                    } else {
                        line.clear();
                        match sock.reader.read_line(&mut line) {
                            Ok(n) if n > 0 => {
                                count_json_response(&mut report, &line);
                                true
                            }
                            _ => false,
                        }
                    };
                    if !ok {
                        report.errors += (*burst - drained) as u64;
                        sock.remaining = 0;
                        break;
                    }
                }
            }
            if !live {
                break;
            }
        }
    } else {
        for round in 0..base + usize::from(rem > 0) {
            for sock in socks.iter_mut() {
                if sock.remaining <= round {
                    continue;
                }
                let digit = cfg.digits[seq as usize % cfg.digits.len()];
                if mix.f64() < cfg.hard_fraction {
                    noisy.render_into(digit, &mut dense)
                } else {
                    clean.render_into(digit, &mut dense)
                };
                encode_request_into(cfg, model_id, seq, &dense, &mut scratch);
                seq += 1;
                if (&sock.stream).write_all(&scratch.out).is_err() {
                    report.errors += 1;
                    sock.remaining = 0;
                    continue;
                }
                report.bytes_sent += scratch.out.len() as u64;
                report.sent += 1;
                // One in flight per shard: read the response right away.
                if binary {
                    match Frame::read_body(&mut sock.reader, &mut frame_body, CLIENT_MAX_FRAME)
                        .and_then(|()| Frame::decode_body(&frame_body))
                    {
                        Ok(frame) => count_binary_response(&mut report, &frame),
                        Err(_) => {
                            report.errors += 1;
                            sock.remaining = 0;
                        }
                    }
                } else {
                    line.clear();
                    match sock.reader.read_line(&mut line) {
                        Ok(n) if n > 0 => count_json_response(&mut report, &line),
                        _ => {
                            report.errors += 1;
                            sock.remaining = 0;
                        }
                    }
                }
            }
        }
    }
    report.bytes_recv = socks.iter().map(|s| s.reader.get_ref().bytes).sum();
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Reusable per-connection encode state: the sparsified support and
/// the wire bytes, all recycled request to request so the load
/// generator itself stays off the allocator (and off the benchmark's
/// CPU profile).
#[derive(Default)]
struct EncodeScratch {
    idx: Vec<u32>,
    val: Vec<f64>,
    out: Vec<u8>,
}

/// Append one JSON float with the same formatting contract as
/// [`crate::util::json::Json::Num`] (integers print bare).
fn push_json_num(out: &mut Vec<u8>, v: f64) {
    use std::io::Write as _;
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

/// Append a `"key":[numbers...]` JSON fragment from a slice.
fn push_json_array<T: Copy + Into<f64>>(out: &mut Vec<u8>, key: &str, values: &[T]) {
    use std::io::Write as _;
    let _ = write!(out, "\"{key}\":[");
    for (i, &v) in values.iter().enumerate() {
        if i > 0 {
            out.push(b',');
        }
        push_json_num(out, v.into());
    }
    out.push(b']');
}

/// Encode one score request as a JSON line straight from slices (the
/// dense or sparse form depending on which slice set is given).
fn encode_score_json_into(
    out: &mut Vec<u8>,
    model: Option<&str>,
    id: u64,
    dense: Option<&[f64]>,
    sparse: Option<(&[u32], &[f64])>,
) {
    use std::io::Write as _;
    out.extend_from_slice(b"{\"op\":\"score\",");
    if let Some(model) = model {
        // Shard names come from the CLI; escape the quote/backslash
        // cases so a hostile name cannot corrupt the line.
        let _ = write!(out, "\"model\":\"{}\",", model.replace('\\', "\\\\").replace('"', "\\\""));
    }
    match (dense, sparse) {
        (Some(features), _) => push_json_array(out, "features", features),
        (None, Some((idx, val))) => {
            push_json_array(out, "idx", idx);
            out.push(b',');
            push_json_array(out, "val", val);
        }
        (None, None) => unreachable!("one payload form is always given"),
    }
    let _ = write!(out, ",\"id\":{id}}}");
    out.push(b'\n');
}

/// Encode one score/classify request on the configured wire into the
/// reusable scratch (`model_id` is the resolved wire id for the binary
/// classify mode). The encoded bytes land in `scratch.out`.
fn encode_request_into(
    cfg: &LoadGenConfig,
    model_id: u16,
    id: u64,
    features: &[f64],
    scratch: &mut EncodeScratch,
) {
    scratch.out.clear();
    match cfg.mode {
        ClientMode::V1Dense => encode_score_json_into(
            &mut scratch.out,
            cfg.model.as_deref(),
            id,
            Some(features),
            None,
        ),
        ClientMode::V2SparseJson => {
            Features::sparsify_into(features, cfg.sparse_eps, &mut scratch.idx, &mut scratch.val);
            encode_score_json_into(
                &mut scratch.out,
                cfg.model.as_deref(),
                id,
                None,
                Some((&scratch.idx, &scratch.val)),
            );
        }
        ClientMode::V2Binary => {
            Features::sparsify_into(features, cfg.sparse_eps, &mut scratch.idx, &mut scratch.val);
            if cfg.deadline_ms > 0 {
                // Deadline runs ride the v7 frame so every request
                // carries its expiry onto the admission queue.
                Frame::put_sparse_ex(
                    &mut scratch.out,
                    0,
                    0,
                    cfg.deadline_ms,
                    LANE_DEFAULT,
                    &scratch.idx,
                    &scratch.val,
                );
            } else {
                // Loadgen traffic is 784-dim digit imagery, far inside
                // the u16 wire bound — checked anyway so a future
                // traffic generator can't silently wrap indices.
                Frame::put_score_sparse(&mut scratch.out, 0, &scratch.idx, &scratch.val)
                    .expect("loadgen payload index exceeds the u16 wire bound");
            }
        }
        ClientMode::Batch => {
            // A lone example still rides the batch frame (the
            // drive_batch_connection hot loop packs multi-example
            // frames itself; this arm keeps the encoder total).
            Features::sparsify_into(features, cfg.sparse_eps, &mut scratch.idx, &mut scratch.val);
            let mut enc = begin_batch_frame(cfg, &mut scratch.out, model_id);
            enc.push_example(&scratch.idx, &scratch.val);
            enc.finish();
        }
        ClientMode::Classify => {
            Features::sparsify_into(features, cfg.sparse_eps, &mut scratch.idx, &mut scratch.val);
            Frame::put_sparse_v3(
                &mut scratch.out,
                crate::server::frame::OP_CLASSIFY_SPARSE,
                model_id,
                0,
                &scratch.idx,
                &scratch.val,
            );
        }
        ClientMode::Learn => {
            Features::sparsify_into(features, cfg.sparse_eps, &mut scratch.idx, &mut scratch.val);
            Frame::put_learn_sparse(
                &mut scratch.out,
                model_id,
                learn_label(cfg, id),
                &scratch.idx,
                &scratch.val,
            );
        }
        ClientMode::Mixed => {
            // Deterministic alternation: even sequence numbers learn,
            // odd ones score — reproducible and exactly half-and-half.
            Features::sparsify_into(features, cfg.sparse_eps, &mut scratch.idx, &mut scratch.val);
            if id % 2 == 0 {
                Frame::put_learn_sparse(
                    &mut scratch.out,
                    model_id,
                    learn_label(cfg, id),
                    &scratch.idx,
                    &scratch.val,
                );
            } else {
                Frame::put_sparse_v3(
                    &mut scratch.out,
                    crate::server::frame::OP_SCORE_SPARSE2,
                    model_id,
                    0,
                    &scratch.idx,
                    &scratch.val,
                );
            }
        }
    }
}

/// Start a batch request frame on the configured wire: the legacy
/// `SCORE_BATCH` layout, or its v7 `SCORE_BATCH_EX` twin carrying the
/// configured deadline when one is set.
fn begin_batch_frame<'o>(
    cfg: &LoadGenConfig,
    out: &'o mut Vec<u8>,
    model_id: u16,
) -> crate::server::frame::BatchEncoder<'o> {
    if cfg.deadline_ms > 0 {
        Frame::begin_score_batch_ex(out, model_id, 0, cfg.deadline_ms, LANE_DEFAULT)
    } else {
        Frame::begin_score_batch(out, model_id, 0)
    }
}

/// One-shot form of [`encode_request_into`] (tests and tools).
#[cfg(test)]
fn encode_request(cfg: &LoadGenConfig, model_id: u16, id: u64, features: Vec<f64>) -> Vec<u8> {
    let mut scratch = EncodeScratch::default();
    encode_request_into(cfg, model_id, id, &features, &mut scratch);
    scratch.out
}

/// Negotiate binary framing on a closed-loop driver connection and, for
/// the modes whose frames carry a wire model id, resolve the configured
/// shard name to that id via the `models` op. This driver targets our
/// own server, so a declined handshake is an error, not a fallback.
/// Returns the resolved wire id (0 = the default shard).
fn binary_handshake(
    cfg: &LoadGenConfig,
    writer: &mut BufWriter<TcpStream>,
    reader: &mut BufReader<CountingReader<TcpStream>>,
    report: &mut LoadReport,
) -> Result<u16> {
    let needed = required_proto(cfg);
    let hello = Request::Hello { proto: PROTO_V7 }.to_line();
    writer
        .write_all(hello.as_bytes())
        .and_then(|()| writer.flush())
        .map_err(|e| Error::io("<loadgen hello>", e))?;
    report.bytes_sent += hello.len() as u64;
    let mut line = String::new();
    let bytes = reader.read_line(&mut line).map_err(|e| Error::io("<loadgen hello>", e))?;
    if bytes == 0 {
        return Err(Error::format("loadgen hello", "connection closed"));
    }
    match Response::parse(line.trim()) {
        Ok(Response::Hello { proto, .. }) if proto >= needed => {}
        other => {
            return Err(Error::format(
                "loadgen hello",
                format!("not granted v{needed}: {other:?}"),
            ))
        }
    }
    let mut model_id = 0u16;
    if routes_by_id(cfg.mode) {
        if let Some(name) = &cfg.model {
            // Resolve the shard name to its wire id via the models
            // op (a JSON envelope frame on this now-binary stream).
            let req = Frame::JsonReq(Request::Models.to_json().to_string_compact()).encode();
            writer
                .write_all(&req)
                .and_then(|()| writer.flush())
                .map_err(|e| Error::io("<loadgen models>", e))?;
            report.bytes_sent += req.len() as u64;
            let entries = match Frame::read_from(reader, CLIENT_MAX_FRAME) {
                Ok(Frame::JsonResp(doc)) => match Response::parse(doc.trim()) {
                    Ok(Response::Models(entries)) => entries,
                    other => {
                        return Err(Error::format(
                            "loadgen models",
                            format!("unexpected reply {other:?}"),
                        ))
                    }
                },
                other => {
                    return Err(Error::format(
                        "loadgen models",
                        format!("unexpected frame {other:?}"),
                    ))
                }
            };
            model_id = entries
                .iter()
                .find(|e| &e.name == name)
                .ok_or_else(|| {
                    Error::format("loadgen models", format!("no shard named {name:?}"))
                })?
                .id;
        }
    }
    Ok(model_id)
}

/// One closed-loop driver connection: the byte-counted reader, the
/// buffered writer, and the wire model id resolved during the
/// handshake (0 for the default shard and the JSON modes).
struct DriverConn {
    reader: BufReader<CountingReader<TcpStream>>,
    writer: BufWriter<TcpStream>,
    model_id: u16,
}

impl DriverConn {
    /// Open one driver connection, running the binary handshake (and
    /// the shard-id lookup) for the frame modes.
    fn open(cfg: &LoadGenConfig, report: &mut LoadReport) -> Result<DriverConn> {
        let stream = TcpStream::connect(&cfg.addr).map_err(|e| Error::io(&cfg.addr, e))?;
        let read_half = stream.try_clone().map_err(|e| Error::io(&cfg.addr, e))?;
        let mut reader = BufReader::new(CountingReader::new(read_half));
        let mut writer = BufWriter::new(stream);
        let binary = matches!(
            cfg.mode,
            ClientMode::V2Binary
                | ClientMode::Batch
                | ClientMode::Classify
                | ClientMode::Learn
                | ClientMode::Mixed
        );
        let mut model_id = 0u16;
        if binary {
            model_id = binary_handshake(cfg, &mut writer, &mut reader, report)?;
        }
        Ok(DriverConn { reader, writer, model_id })
    }
}

/// Replace a dead driver connection: fold the dead socket's read-byte
/// tally into the report, back off with jitter, reopen (re-running the
/// handshake), and count the `resent` requests the caller is about to
/// replay. Returns `false` when the reconnect attempt itself fails —
/// callers stop and report what they have.
fn reconnect_driver(
    cfg: &LoadGenConfig,
    report: &mut LoadReport,
    conn: &mut DriverConn,
    rng: &mut Rng64,
    attempt: u32,
    resent: u64,
) -> bool {
    report.bytes_recv += conn.reader.get_ref().bytes;
    report.retries += resent;
    report.reconnects += 1;
    std::thread::sleep(retry_backoff(rng, &RetryPolicy::default(), attempt));
    match DriverConn::open(cfg, report) {
        Ok(fresh) => {
            *conn = fresh;
            true
        }
        Err(_) => false,
    }
}

/// One batch-mode connection: the same digit traffic as the `v2-binary`
/// singles mode, but packed `LoadGenConfig.batch_size` examples per
/// `SCORE_BATCH` frame with the pipelining window counted in frames.
/// `n` counts *examples* — `sent` / `answered` tally per example, so
/// the pass's `req_per_s` divides by the singles pass's to give the
/// batching speedup directly.
fn drive_batch_connection(cfg: &LoadGenConfig, conn_id: u64, n: usize) -> Result<LoadReport> {
    let mut report = LoadReport::default();
    if n == 0 {
        return Ok(report);
    }
    let batch = cfg.batch_size.max(1);
    let mut conn = DriverConn::open(cfg, &mut report)?;
    let mut retries_left = cfg.retries;

    let base = cfg.seed.wrapping_add(conn_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut clean = SynthDigits::new(base);
    let mut noisy = SynthDigits::with_config(base ^ 0xA5A5_A5A5, hard_render_config());
    let mut mix = Rng64::seed_from_u64(base ^ 0x5A5A_5A5A);
    let mut backoff_rng = Rng64::seed_from_u64(base ^ 0x0F0F_0F0F);

    // Reusable buffers as in drive_connection: render, sparsify, and
    // encode whole batch frames with zero steady-state allocation.
    let mut dense = Vec::new();
    let mut scratch = EncodeScratch::default();
    let mut frame_body = Vec::new();

    let frames = n.div_ceil(batch);
    let t0 = Instant::now();
    let mut next = 0usize; // frames encoded + sent
    let mut received = 0usize; // response frames read
    let mut seq = 0u64; // examples rendered (digit cycle position)
    while received < frames {
        // Fill the pipelining window (counted in frames, so a batch
        // run keeps `pipeline * batch_size` examples in flight).
        if next < frames && next - received < cfg.pipeline {
            // The last frame carries the remainder.
            let count = batch.min(n - next * batch);
            scratch.out.clear();
            let mut enc = begin_batch_frame(cfg, &mut scratch.out, conn.model_id);
            for _ in 0..count {
                let digit = cfg.digits[seq as usize % cfg.digits.len()];
                if mix.f64() < cfg.hard_fraction {
                    noisy.render_into(digit, &mut dense)
                } else {
                    clean.render_into(digit, &mut dense)
                };
                Features::sparsify_into(
                    &dense,
                    cfg.sparse_eps,
                    &mut scratch.idx,
                    &mut scratch.val,
                );
                enc.push_example(&scratch.idx, &scratch.val);
                seq += 1;
            }
            enc.finish();
            let flush_now = !(next + 1 < frames && next + 1 - received < cfg.pipeline);
            let wrote = conn.writer.write_all(&scratch.out).and_then(|()| {
                if flush_now { conn.writer.flush() } else { Ok(()) }
            });
            match wrote {
                Ok(()) => {
                    report.bytes_sent += scratch.out.len() as u64;
                    report.sent += count as u64;
                    next += 1;
                    if !flush_now {
                        continue; // keep filling before the (blocking) read
                    }
                }
                Err(e) => {
                    if retries_left == 0 {
                        return Err(Error::io("<loadgen write>", e));
                    }
                    retries_left -= 1;
                    let resent = resent_examples(received, next, batch, n);
                    let attempt = cfg.retries - retries_left;
                    let ok = reconnect_driver(
                        cfg, &mut report, &mut conn, &mut backoff_rng, attempt, resent,
                    );
                    if !ok {
                        report.errors += 1;
                        break;
                    }
                    next = received;
                    continue;
                }
            }
        }
        // Window full (or everything sent): read one response frame,
        // which tallies one row per example it carries.
        match Frame::read_body(&mut conn.reader, &mut frame_body, CLIENT_MAX_FRAME)
            .and_then(|()| Frame::decode_body(&frame_body))
        {
            Ok(frame) => {
                received += 1;
                // Progress refreshes the budget: `retries` bounds
                // *consecutive* recoveries, so long runs survive
                // periodic faults without an unbounded total.
                retries_left = cfg.retries;
                count_binary_response(&mut report, &frame);
            }
            Err(e) => {
                // The stream died under us (reset, truncated frame):
                // with retry budget left, replay the unanswered window
                // on a fresh connection; otherwise report what we have.
                if retries_left == 0 {
                    if !matches!(e, FrameError::Eof) {
                        report.errors += 1;
                    }
                    break;
                }
                retries_left -= 1;
                let resent = resent_examples(received, next, batch, n);
                let attempt = cfg.retries - retries_left;
                let ok = reconnect_driver(
                    cfg, &mut report, &mut conn, &mut backoff_rng, attempt, resent,
                );
                if !ok {
                    report.errors += 1;
                    break;
                }
                next = received;
            }
        }
    }
    report.bytes_recv += conn.reader.get_ref().bytes;
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Examples carried by the in-flight batch frames `[received, next)` —
/// the replay size after a batch-mode reconnect (every frame holds
/// `batch` examples except a short final remainder).
fn resent_examples(received: usize, next: usize, batch: usize, n: usize) -> u64 {
    (received..next).map(|f| batch.min(n - f * batch) as u64).sum()
}

/// One connection's worth of traffic: keep up to `pipeline` requests in
/// flight, count every response class.
fn drive_connection(cfg: &LoadGenConfig, conn_id: u64, n: usize) -> Result<LoadReport> {
    let mut report = LoadReport::default();
    if n == 0 {
        return Ok(report);
    }
    let mut line = String::new();

    // The binary modes negotiate their framing before any traffic
    // (inside `DriverConn::open`); this driver targets our own server,
    // so a declined handshake is an error, not a fallback. Classify
    // additionally needs the v3 frame ops, learn/mixed the v4 learn
    // frame, and the routed modes the model's wire id.
    let binary = matches!(
        cfg.mode,
        ClientMode::V2Binary | ClientMode::Classify | ClientMode::Learn | ClientMode::Mixed
    );
    let mut conn = DriverConn::open(cfg, &mut report)?;
    let mut retries_left = cfg.retries;

    let base = cfg.seed.wrapping_add(conn_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut clean = SynthDigits::new(base);
    let mut noisy = SynthDigits::with_config(base ^ 0xA5A5_A5A5, hard_render_config());
    let mut mix = Rng64::seed_from_u64(base ^ 0x5A5A_5A5A);
    let mut backoff_rng = Rng64::seed_from_u64(base ^ 0x0F0F_0F0F);

    // Reusable per-connection buffers: the send loop renders,
    // sparsifies, and encodes with zero steady-state allocation, so
    // client CPU measures the wire, not the generator.
    let mut dense = Vec::new();
    let mut scratch = EncodeScratch::default();
    let mut frame_body = Vec::new();

    let t0 = Instant::now();
    let mut next = 0usize;
    let mut received = 0usize;
    while received < n {
        // Fill the pipelining window.
        let in_flight = next - received;
        if next < n && in_flight < cfg.pipeline {
            let digit = cfg.digits[next % cfg.digits.len()];
            if mix.f64() < cfg.hard_fraction {
                noisy.render_into(digit, &mut dense)
            } else {
                clean.render_into(digit, &mut dense)
            };
            encode_request_into(cfg, conn.model_id, next as u64, &dense, &mut scratch);
            let flush_now = !(next + 1 < n && next + 1 - received < cfg.pipeline);
            let wrote = conn.writer.write_all(&scratch.out).and_then(|()| {
                if flush_now { conn.writer.flush() } else { Ok(()) }
            });
            match wrote {
                Ok(()) => {
                    report.bytes_sent += scratch.out.len() as u64;
                    report.sent += 1;
                    next += 1;
                    if !flush_now {
                        continue; // keep filling before the (blocking) read
                    }
                }
                Err(e) => {
                    if retries_left == 0 {
                        return Err(Error::io("<loadgen write>", e));
                    }
                    retries_left -= 1;
                    let attempt = cfg.retries - retries_left;
                    let resent = (next - received) as u64;
                    let ok = reconnect_driver(
                        cfg, &mut report, &mut conn, &mut backoff_rng, attempt, resent,
                    );
                    if !ok {
                        report.errors += 1;
                        break;
                    }
                    next = received;
                    continue;
                }
            }
        }
        // Window full (or everything sent): read one response.
        if binary {
            match Frame::read_body(&mut conn.reader, &mut frame_body, CLIENT_MAX_FRAME)
                .and_then(|()| Frame::decode_body(&frame_body))
            {
                Ok(frame) => {
                    received += 1;
                    retries_left = cfg.retries; // progress refreshes the budget
                    count_binary_response(&mut report, &frame);
                }
                Err(e) => {
                    // Framing lost or the server dropped us: nothing
                    // more on this stream is decodable. With retry
                    // budget left, replay the unanswered window on a
                    // fresh connection; otherwise report what we have.
                    if retries_left == 0 {
                        if !matches!(e, FrameError::Eof) {
                            report.errors += 1;
                        }
                        break;
                    }
                    retries_left -= 1;
                    let attempt = cfg.retries - retries_left;
                    let resent = (next - received) as u64;
                    let ok = reconnect_driver(
                        cfg, &mut report, &mut conn, &mut backoff_rng, attempt, resent,
                    );
                    if !ok {
                        report.errors += 1;
                        break;
                    }
                    next = received;
                }
            }
        } else {
            line.clear();
            match conn.reader.read_line(&mut line) {
                Ok(bytes) if bytes > 0 => {
                    received += 1;
                    retries_left = cfg.retries; // progress refreshes the budget
                    count_json_response(&mut report, &line);
                }
                other => {
                    if retries_left == 0 {
                        if let Err(e) = other {
                            return Err(Error::io("<loadgen read>", e));
                        }
                        break; // server closed on us; report what we have
                    }
                    retries_left -= 1;
                    let attempt = cfg.retries - retries_left;
                    let resent = (next - received) as u64;
                    let ok = reconnect_driver(
                        cfg, &mut report, &mut conn, &mut backoff_rng, attempt, resent,
                    );
                    if !ok {
                        report.errors += 1;
                        break;
                    }
                    next = received;
                }
            }
        }
    }
    report.bytes_recv += conn.reader.get_ref().bytes;
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_merge_and_ratios() {
        let mut a = LoadReport {
            sent: 10,
            answered: 9,
            learned: 0,
            overloaded: 1,
            errors: 0,
            total_features: 900,
            bytes_sent: 1_000,
            bytes_recv: 500,
            elapsed_s: 2.0,
            features: vec![100; 9],
            total_voters: 27,
            churned: 2,
            retries: 1,
            reconnects: 1,
            deadline_sheds: 3,
            degraded: 4,
        };
        let b = LoadReport {
            sent: 5,
            answered: 5,
            learned: 0,
            overloaded: 0,
            errors: 0,
            total_features: 100,
            bytes_sent: 200,
            bytes_recv: 100,
            elapsed_s: 1.0,
            features: vec![20; 5],
            total_voters: 0,
            churned: 1,
            retries: 2,
            reconnects: 0,
            deadline_sheds: 1,
            degraded: 0,
        };
        a.merge(&b);
        assert_eq!(a.sent, 15);
        assert_eq!(a.answered, 14);
        assert_eq!(a.bytes_sent, 1_200);
        assert_eq!(a.bytes_recv, 600);
        assert_eq!(a.elapsed_s, 2.0, "merged elapsed is the max");
        assert!((a.avg_features() - 1000.0 / 14.0).abs() < 1e-9);
        assert!((a.req_per_s() - 15.0 / 2.0).abs() < 1e-9);
        assert!((a.bytes_per_req() - 80.0).abs() < 1e-9);
        assert_eq!(a.total_voters, 27);
        assert!((a.avg_features_per_voter() - 1000.0 / 27.0).abs() < 1e-9);
        assert_eq!(a.churned, 3);
        assert_eq!(a.retries, 3);
        assert_eq!(a.reconnects, 1);
        assert_eq!(a.deadline_sheds, 4);
        assert_eq!(a.degraded, 4);
    }

    #[test]
    fn retry_backoff_doubles_caps_and_jitters_within_bounds() {
        let policy = RetryPolicy { max_retries: 8, base_backoff_ms: 10, max_backoff_ms: 100 };
        let mut rng = Rng64::seed_from_u64(7);
        for attempt in 1..=8u32 {
            let exp = (10u64 << (attempt - 1)).min(100);
            for _ in 0..50 {
                let ms = retry_backoff(&mut rng, &policy, attempt).as_millis() as u64;
                let lo = exp / 2;
                assert!(ms >= lo && ms <= exp, "attempt {attempt}: {ms}ms outside [{lo}, {exp}]");
            }
        }
        // A degenerate zero-base policy still sleeps a bounded, nonzero
        // window rather than spinning.
        let zero = RetryPolicy { max_retries: 1, base_backoff_ms: 0, max_backoff_ms: 0 };
        let ms = retry_backoff(&mut rng, &zero, 1).as_millis();
        assert!(ms <= 1);
    }

    #[test]
    fn client_mode_names_round_trip() {
        for mode in ClientMode::ALL {
            assert_eq!(ClientMode::from_name(mode.name()).unwrap(), mode);
        }
        assert_eq!(ClientMode::from_name("classify").unwrap(), ClientMode::Classify);
        assert_eq!(ClientMode::from_name("learn").unwrap(), ClientMode::Learn);
        assert_eq!(ClientMode::from_name("mixed").unwrap(), ClientMode::Mixed);
        assert_eq!(ClientMode::from_name("batch").unwrap(), ClientMode::Batch);
        assert!(
            !ClientMode::ALL.contains(&ClientMode::Batch),
            "the three-way transport sweep stays single-request; batch is its own pass"
        );
        assert!(
            !ClientMode::ALL.contains(&ClientMode::Classify),
            "the transport sweep drives binary shards only"
        );
        assert!(
            !ClientMode::ALL.contains(&ClientMode::Learn)
                && !ClientMode::ALL.contains(&ClientMode::Mixed),
            "learn traffic needs a trainer-enabled server; it is driven separately"
        );
        assert!(ClientMode::from_name("v3-quantum").is_err());
        assert_eq!(ClientMode::default(), ClientMode::V1Dense);
    }

    #[test]
    fn request_encodings_differ_by_mode() {
        // Full-precision values like real pixel traffic: JSON floats
        // serialize at ~17 chars, which is what the binary frame beats.
        let features: Vec<f64> = (0..784)
            .map(|i| if i % 5 == 0 { 0.1234567890123 + i as f64 * 1e-7 } else { 0.0 })
            .collect();
        let cfg = |mode: ClientMode| LoadGenConfig { mode, ..Default::default() };
        let dense = encode_request(&cfg(ClientMode::V1Dense), 0, 0, features.clone());
        let sparse_json = encode_request(&cfg(ClientMode::V2SparseJson), 0, 0, features.clone());
        let binary = encode_request(&cfg(ClientMode::V2Binary), 0, 0, features.clone());
        assert!(
            sparse_json.len() < dense.len(),
            "sparse JSON ({}) must undercut dense JSON ({})",
            sparse_json.len(),
            dense.len()
        );
        assert!(
            binary.len() < sparse_json.len(),
            "binary ({}) must undercut sparse JSON ({})",
            binary.len(),
            sparse_json.len()
        );
        // The binary encoding is an exact frame: 4 (len) + 1 (op) +
        // 4 (gen) + 2 (nnz) + 10 per pair.
        let nnz = features.iter().filter(|v| v.abs() > 0.05).count();
        assert_eq!(nnz, 157);
        assert_eq!(binary.len(), 11 + 10 * nnz);
        // Sparse modes parse back to the same support.
        let parsed = Request::parse(std::str::from_utf8(&sparse_json).unwrap().trim()).unwrap();
        match parsed {
            Request::Score { features: Features::Sparse { idx, .. }, .. } => {
                assert_eq!(idx.len(), nnz)
            }
            other => panic!("wrong variant {other:?}"),
        }
        // Classify mode: an exact v3 frame — 4 (len) + 1 (op) +
        // 2 (model) + 4 (gen) + 4 (nnz) + 12 per pair — carrying the
        // resolved model id.
        let classify = encode_request(&cfg(ClientMode::Classify), 5, 0, features.clone());
        assert_eq!(classify.len(), 15 + 12 * nnz);
        let (frame, used) = Frame::decode(&classify, 1 << 20).unwrap();
        assert_eq!(used, classify.len());
        match frame {
            Frame::ClassifySparse { model, gen, idx, .. } => {
                assert_eq!(model, 5);
                assert_eq!(gen, 0);
                assert_eq!(idx.len(), nnz);
            }
            other => panic!("wrong frame {other:?}"),
        }
        // Learn mode: an exact v4 frame — 4 (len) + 1 (op) + 2 (model) +
        // 1 (label) + 4 (nnz) + 12 per pair. Sequence 0 renders the
        // pair's first digit, so the label is +1; sequence 1 is -1.
        let learn = encode_request(&cfg(ClientMode::Learn), 3, 0, features.clone());
        assert_eq!(learn.len(), 12 + 12 * nnz);
        match Frame::decode(&learn, 1 << 20).unwrap().0 {
            Frame::LearnSparse { model, label, idx, .. } => {
                assert_eq!(model, 3);
                assert_eq!(label, 1);
                assert_eq!(idx.len(), nnz);
            }
            other => panic!("wrong frame {other:?}"),
        }
        match Frame::decode(&encode_request(&cfg(ClientMode::Learn), 3, 1, features.clone()), 1 << 20)
            .unwrap()
            .0
        {
            Frame::LearnSparse { label, .. } => assert_eq!(label, -1),
            other => panic!("wrong frame {other:?}"),
        }
        // Mixed mode alternates: even sequences learn, odd ones score.
        match Frame::decode(&encode_request(&cfg(ClientMode::Mixed), 0, 2, features.clone()), 1 << 20)
            .unwrap()
            .0
        {
            Frame::LearnSparse { .. } => {}
            other => panic!("wrong frame {other:?}"),
        }
        match Frame::decode(&encode_request(&cfg(ClientMode::Mixed), 0, 3, features.clone()), 1 << 20)
            .unwrap()
            .0
        {
            Frame::ScoreSparse2 { .. } => {}
            other => panic!("wrong frame {other:?}"),
        }
        // A routed JSON score carries the model name.
        let routed = LoadGenConfig {
            mode: ClientMode::V2SparseJson,
            model: Some("pair-a".into()),
            ..Default::default()
        };
        let bytes = encode_request(&routed, 0, 0, features);
        match Request::parse(std::str::from_utf8(&bytes).unwrap().trim()).unwrap() {
            Request::Score { model, .. } => assert_eq!(model.as_deref(), Some("pair-a")),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn batch_mode_encodes_score_batch_frames() {
        let features: Vec<f64> = (0..784)
            .map(|i| if i % 5 == 0 { 0.1234567890123 + i as f64 * 1e-7 } else { 0.0 })
            .collect();
        let nnz = features.iter().filter(|v| v.abs() > 0.05).count();
        let cfg = LoadGenConfig { mode: ClientMode::Batch, ..Default::default() };
        let bytes = encode_request(&cfg, 9, 0, features);
        // An exact one-example SCORE_BATCH frame: 4 (len) + 1 (op) +
        // 2 (model) + 4 (gen) + 2 (count) + 4 (nnz) + 12 per pair.
        assert_eq!(bytes.len(), 17 + 12 * nnz);
        let (frame, used) = Frame::decode(&bytes, 1 << 20).unwrap();
        assert_eq!(used, bytes.len());
        match frame {
            Frame::ScoreBatch { model, gen, examples } => {
                assert_eq!(model, 9);
                assert_eq!(gen, 0);
                assert_eq!(examples.len(), 1);
                assert_eq!(examples[0].0.len(), nnz);
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn batch_response_rows_tally_per_example() {
        let mut report = LoadReport::default();
        let frame = Frame::ScoreBatchResp {
            gen: 3,
            results: vec![
                BatchResult { status: BATCH_STATUS_OK, evaluated: 40, score: 1.5 },
                BatchResult { status: ErrorCode::BadRequest as u8, evaluated: 0, score: 0.0 },
                BatchResult { status: ErrorCode::Overloaded as u8, evaluated: 0, score: 0.0 },
                BatchResult { status: BATCH_STATUS_OK, evaluated: 60, score: -0.5 },
            ],
        };
        count_binary_response(&mut report, &frame);
        assert_eq!(report.answered, 2, "one tally per OK row");
        assert_eq!(report.errors, 1);
        assert_eq!(report.overloaded, 1);
        assert_eq!(report.total_features, 100);
        assert_eq!(report.features, vec![40, 60]);
        assert_eq!(report.degraded, 0, "legacy batch frames never carry the degraded flag");
    }

    #[test]
    fn v7_responses_tally_sheds_and_degradation() {
        // A degraded EX batch: OK rows count as answered *and*
        // degraded; a deadline-shed row lands in its own bucket.
        let mut report = LoadReport::default();
        let frame = Frame::ScoreBatchRespEx {
            gen: 3,
            flags: FLAG_DEGRADED,
            results: vec![
                BatchResult { status: BATCH_STATUS_OK, evaluated: 40, score: 1.5 },
                BatchResult {
                    status: ErrorCode::DeadlineExceeded as u8,
                    evaluated: 0,
                    score: 0.0,
                },
                BatchResult { status: BATCH_STATUS_OK, evaluated: 60, score: -0.5 },
            ],
        };
        count_binary_response(&mut report, &frame);
        assert_eq!(report.answered, 2);
        assert_eq!(report.degraded, 2);
        assert_eq!(report.deadline_sheds, 1);
        assert_eq!(report.errors, 0, "a deadline shed is not a transport error");

        // Single-frame EX responses and the bare error frame.
        let mut report = LoadReport::default();
        count_binary_response(
            &mut report,
            &Frame::ScoreEx { gen: 1, flags: FLAG_DEGRADED, evaluated: 7, score: 0.5 },
        );
        count_binary_response(
            &mut report,
            &Frame::ScoreEx { gen: 1, flags: 0, evaluated: 9, score: 0.5 },
        );
        count_binary_response(
            &mut report,
            &Frame::Error {
                code: ErrorCode::DeadlineExceeded,
                retryable: true,
                msg: String::new(),
            },
        );
        assert_eq!(report.answered, 2);
        assert_eq!(report.degraded, 1);
        assert_eq!(report.deadline_sheds, 1);
        assert_eq!(report.errors, 0);

        // The JSON twin: a degraded score and a deadline-shed error
        // (rendered through the real response serializer so the tally
        // sees exactly the server's line format).
        let mut report = LoadReport::default();
        let score =
            Response::Score { id: None, score: 1.0, features_evaluated: 5, degraded: true };
        count_json_response(&mut report, &score.to_line());
        let shed = Response::Error {
            id: None,
            error: "deadline exceeded before scoring (shed at dequeue; retry)".into(),
            retryable: true,
        };
        assert!(shed.is_deadline_exceeded());
        count_json_response(&mut report, &shed.to_line());
        assert_eq!(report.answered, 1);
        assert_eq!(report.degraded, 1);
        assert_eq!(report.deadline_sheds, 1);
        assert_eq!(report.errors, 0);
    }

    #[test]
    fn deadline_config_switches_binary_modes_to_ex_frames() {
        let features: Vec<f64> = (0..784)
            .map(|i| if i % 5 == 0 { 0.1234567890123 + i as f64 * 1e-7 } else { 0.0 })
            .collect();
        let nnz = features.iter().filter(|v| v.abs() > 0.05).count();
        let cfg = LoadGenConfig {
            mode: ClientMode::V2Binary,
            deadline_ms: 25,
            ..Default::default()
        };
        assert_eq!(required_proto(&cfg), PROTO_V7);
        let bytes = encode_request(&cfg, 0, 0, features.clone());
        match Frame::decode(&bytes, 1 << 20).unwrap().0 {
            Frame::ScoreSparseEx { deadline_ms, lane, idx, .. } => {
                assert_eq!(deadline_ms, 25);
                assert_eq!(lane, LANE_DEFAULT);
                assert_eq!(idx.len(), nnz);
            }
            other => panic!("wrong frame {other:?}"),
        }
        let cfg = LoadGenConfig {
            mode: ClientMode::Batch,
            deadline_ms: 40,
            ..Default::default()
        };
        assert_eq!(required_proto(&cfg), PROTO_V7);
        let bytes = encode_request(&cfg, 9, 0, features);
        match Frame::decode(&bytes, 1 << 20).unwrap().0 {
            Frame::ScoreBatchEx { model, deadline_ms, lane, examples, .. } => {
                assert_eq!(model, 9);
                assert_eq!(deadline_ms, 40);
                assert_eq!(lane, LANE_DEFAULT);
                assert_eq!(examples.len(), 1);
            }
            other => panic!("wrong frame {other:?}"),
        }
        // Without a deadline the legacy frames (and proto floors) stay.
        let cfg = LoadGenConfig { mode: ClientMode::V2Binary, ..Default::default() };
        assert_eq!(required_proto(&cfg), PROTO_V2);
    }

    #[test]
    fn batch_window_count_trigger_fires_at_k() {
        let now = Instant::now();
        let mut w = BatchWindow::new(3, 1_000_000).unwrap();
        assert!(!w.note_push(now), "1 of 3 buffered");
        assert!(!w.note_push(now), "2 of 3 buffered");
        assert!(w.note_push(now), "the k-th push flushes");
        w.reset();
        assert!(!w.due(now), "reset forgets the oldest arrival");
        assert!(!w.note_push(now), "the count restarts after a flush");
        assert!(BatchWindow::new(0, 10).is_err(), "k = 0 can never flush");
    }

    #[test]
    fn batch_window_time_trigger_fires_after_window() {
        let t0 = Instant::now();
        let mut w = BatchWindow::new(100, 500).unwrap();
        assert!(!w.due(t0), "an empty window is never due");
        assert!(!w.note_push(t0), "1 of 100, window fresh");
        let before = t0 + std::time::Duration::from_micros(499);
        let after = t0 + std::time::Duration::from_micros(500);
        assert!(!w.due(before), "window not yet elapsed");
        assert!(w.due(after), "window elapsed since the oldest push");
        assert!(
            w.note_push(after),
            "a push after the window expires flushes even far below k"
        );
        w.reset();
        assert!(!w.due(after + std::time::Duration::from_secs(1)), "flushing rearms the window");
    }

    #[test]
    fn percentiles_are_exact_over_collected_counts() {
        let report = LoadReport {
            features: (1..=100).collect(),
            answered: 100,
            ..Default::default()
        };
        assert_eq!(report.feature_percentile(0.0), 1);
        assert_eq!(report.feature_percentile(0.5), 51);
        assert_eq!(report.feature_percentile(1.0), 100);
        assert_eq!(LoadReport::default().feature_percentile(0.5), 0);
    }

    #[test]
    fn empty_report_ratios_are_safe() {
        let r = LoadReport::default();
        assert_eq!(r.avg_features(), 0.0);
        assert_eq!(r.req_per_s(), 0.0);
    }
}
