//! Load-generator client for the TCP serving front-end.
//!
//! Two layers:
//!
//! * [`Client`] — a synchronous request/response connection, used as the
//!   control channel (ping / stats / reload) and for one-off scoring.
//! * [`run`] — the load generator proper: `connections` client threads
//!   drive the server over loopback (or any address) with a configurable
//!   pipelining window and an easy/hard traffic mix — clean synthetic
//!   digits exit early, heavily-noised ones force deep evaluations — and
//!   the merged [`LoadReport`] carries per-request features-touched
//!   counts for exact percentile reporting.
//!
//! Traffic is 784-dimensional digit imagery (the paper's MNIST shape);
//! point it at a server that serves a 784-dim model.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::coordinator::service::ModelSnapshot;
use crate::data::synth::{SynthConfig, SynthDigits};
use crate::error::{Error, Result};
use crate::server::protocol::{Request, Response, StatsReport};
use crate::util::rng::Rng64;

/// A synchronous JSON-lines client connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    /// Connect to a serving front-end.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).map_err(|e| Error::io(addr, e))?;
        let read_half = stream.try_clone().map_err(|e| Error::io(addr, e))?;
        Ok(Client { reader: BufReader::new(read_half), writer: BufWriter::new(stream) })
    }

    /// Send one request and wait for its response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        let line = req.to_line();
        self.writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.flush())
            .map_err(|e| Error::io("<client write>", e))?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(|e| Error::io("<client read>", e))?;
        if n == 0 {
            return Err(Error::format("server reply", "connection closed"));
        }
        Response::parse(reply.trim()).map_err(|e| Error::format("server reply", e))
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(Error::format("ping reply", format!("unexpected {other:?}"))),
        }
    }

    /// Score one feature vector.
    pub fn score(&mut self, features: Vec<f64>) -> Result<Response> {
        self.call(&Request::Score { id: None, features })
    }

    /// Fetch server statistics.
    pub fn stats(&mut self) -> Result<StatsReport> {
        match self.call(&Request::Stats)? {
            Response::Stats(report) => Ok(report),
            other => Err(Error::format("stats reply", format!("unexpected {other:?}"))),
        }
    }

    /// Hot-swap the serving model; returns the new dimensionality.
    pub fn reload(&mut self, snapshot: &ModelSnapshot) -> Result<usize> {
        match self.call(&Request::Reload { snapshot: snapshot.clone() })? {
            Response::Reloaded { dim } => Ok(dim),
            Response::Error { error, .. } => Err(Error::format("reload reply", error)),
            other => Err(Error::format("reload reply", format!("unexpected {other:?}"))),
        }
    }
}

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Server address, e.g. `"127.0.0.1:7878"`.
    pub addr: String,
    /// Concurrent client connections.
    pub connections: usize,
    /// Total requests across all connections.
    pub requests: usize,
    /// In-flight requests per connection (pipelining window).
    pub pipeline: usize,
    /// Fraction of requests rendered with heavy noise (hard inputs that
    /// defeat the early exit); the rest are clean (easy).
    pub hard_fraction: f64,
    /// Base RNG seed (per-connection streams are derived from it).
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            connections: 4,
            requests: 1_000,
            pipeline: 8,
            hard_fraction: 0.5,
            seed: 0,
        }
    }
}

/// Merged outcome of a load-generation run.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Requests written to the wire.
    pub sent: u64,
    /// Score responses received.
    pub answered: u64,
    /// Explicit `overloaded` shed responses received.
    pub overloaded: u64,
    /// Other error responses (protocol, dimension, transport).
    pub errors: u64,
    /// Sum of features touched over answered requests.
    pub total_features: u64,
    /// Wall-clock seconds (max over connections).
    pub elapsed_s: f64,
    /// Features touched per answered request (for exact percentiles).
    pub features: Vec<u32>,
}

impl LoadReport {
    /// Mean features touched per answered request.
    pub fn avg_features(&self) -> f64 {
        if self.answered == 0 { 0.0 } else { self.total_features as f64 / self.answered as f64 }
    }

    /// Responses (answered + shed) per second.
    pub fn req_per_s(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            0.0
        } else {
            (self.answered + self.overloaded) as f64 / self.elapsed_s
        }
    }

    /// Exact `p`-th percentile (`p ∈ [0, 1]`) of features touched.
    pub fn feature_percentile(&self, p: f64) -> u32 {
        if self.features.is_empty() {
            return 0;
        }
        let mut sorted = self.features.clone();
        sorted.sort_unstable();
        let idx = (p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx]
    }

    /// Fold another connection's report into this one.
    pub fn merge(&mut self, other: &LoadReport) {
        self.sent += other.sent;
        self.answered += other.answered;
        self.overloaded += other.overloaded;
        self.errors += other.errors;
        self.total_features += other.total_features;
        self.elapsed_s = self.elapsed_s.max(other.elapsed_s);
        self.features.extend_from_slice(&other.features);
    }
}

/// Renderer config for the hard (heavily-noised) traffic class.
fn hard_render_config() -> SynthConfig {
    SynthConfig { pixel_noise: 0.35, salt_prob: 0.2, jitter_px: 4.0, ..Default::default() }
}

/// Drive the server with mixed easy/hard digit traffic and merge the
/// per-connection reports.
pub fn run(cfg: &LoadGenConfig) -> Result<LoadReport> {
    if cfg.connections == 0 || cfg.pipeline == 0 {
        return Err(Error::Config("loadgen connections and pipeline must be >= 1".into()));
    }
    let per_conn = cfg.requests / cfg.connections;
    let remainder = cfg.requests % cfg.connections;
    let reports = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..cfg.connections {
            let n = per_conn + usize::from(c < remainder);
            joins.push(scope.spawn(move || drive_connection(cfg, c as u64, n)));
        }
        joins.into_iter().map(|j| j.join().expect("loadgen thread panicked")).collect::<Vec<_>>()
    });
    let mut merged = LoadReport::default();
    for r in reports {
        merged.merge(&r?);
    }
    Ok(merged)
}

/// One connection's worth of traffic: keep up to `pipeline` requests in
/// flight, count every response class.
fn drive_connection(cfg: &LoadGenConfig, conn_id: u64, n: usize) -> Result<LoadReport> {
    let mut report = LoadReport::default();
    if n == 0 {
        return Ok(report);
    }
    let stream = TcpStream::connect(&cfg.addr).map_err(|e| Error::io(&cfg.addr, e))?;
    let read_half = stream.try_clone().map_err(|e| Error::io(&cfg.addr, e))?;
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);

    let base = cfg.seed.wrapping_add(conn_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut clean = SynthDigits::new(base);
    let mut noisy = SynthDigits::with_config(base ^ 0xA5A5_A5A5, hard_render_config());
    let mut mix = Rng64::seed_from_u64(base ^ 0x5A5A_5A5A);

    let t0 = Instant::now();
    let mut next = 0usize;
    let mut received = 0usize;
    let mut line = String::new();
    while received < n {
        // Fill the pipelining window.
        let in_flight = next - received;
        if next < n && in_flight < cfg.pipeline {
            let digit = if next % 2 == 0 { 2u8 } else { 3u8 };
            let features = if mix.f64() < cfg.hard_fraction {
                noisy.render(digit)
            } else {
                clean.render(digit)
            };
            let req = Request::Score { id: Some(next as u64), features };
            writer
                .write_all(req.to_line().as_bytes())
                .map_err(|e| Error::io("<loadgen write>", e))?;
            report.sent += 1;
            next += 1;
            if next < n && next - received < cfg.pipeline {
                continue; // keep filling before the (blocking) read
            }
            writer.flush().map_err(|e| Error::io("<loadgen flush>", e))?;
        }
        // Window full (or everything sent): read one response.
        line.clear();
        let bytes = reader.read_line(&mut line).map_err(|e| Error::io("<loadgen read>", e))?;
        if bytes == 0 {
            break; // server closed on us; report what we have
        }
        received += 1;
        match Response::parse(line.trim()) {
            Ok(Response::Score { features_evaluated, .. }) => {
                report.answered += 1;
                report.total_features += features_evaluated as u64;
                report.features.push(features_evaluated as u32);
            }
            Ok(resp) if resp.is_overloaded() => report.overloaded += 1,
            _ => report.errors += 1,
        }
    }
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_merge_and_ratios() {
        let mut a = LoadReport {
            sent: 10,
            answered: 9,
            overloaded: 1,
            errors: 0,
            total_features: 900,
            elapsed_s: 2.0,
            features: vec![100; 9],
        };
        let b = LoadReport {
            sent: 5,
            answered: 5,
            overloaded: 0,
            errors: 0,
            total_features: 100,
            elapsed_s: 1.0,
            features: vec![20; 5],
        };
        a.merge(&b);
        assert_eq!(a.sent, 15);
        assert_eq!(a.answered, 14);
        assert_eq!(a.elapsed_s, 2.0, "merged elapsed is the max");
        assert!((a.avg_features() - 1000.0 / 14.0).abs() < 1e-9);
        assert!((a.req_per_s() - 15.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_are_exact_over_collected_counts() {
        let report = LoadReport {
            features: (1..=100).collect(),
            answered: 100,
            ..Default::default()
        };
        assert_eq!(report.feature_percentile(0.0), 1);
        assert_eq!(report.feature_percentile(0.5), 51);
        assert_eq!(report.feature_percentile(1.0), 100);
        assert_eq!(LoadReport::default().feature_percentile(0.5), 0);
    }

    #[test]
    fn empty_report_ratios_are_safe() {
        let r = LoadReport::default();
        assert_eq!(r.avg_features(), 0.0);
        assert_eq!(r.req_per_s(), 0.0);
    }
}
