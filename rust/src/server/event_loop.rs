//! Epoll event-loop transport backend: thousands of connections on a
//! handful of I/O threads, with an allocation-free steady-state request
//! path.
//!
//! ## Why
//!
//! The thread backend burns a reader+writer thread pair per connection:
//! correct and simple, but connection count is capped by thread
//! exhaustion long before the attentive evaluator saturates — exactly
//! backwards for a paper whose point is that per-request *compute* is
//! cheap. This backend multiplexes every connection over
//! `ServerConfig.event_threads` sharded epoll loops instead, so idle
//! connections cost one `epoll_ctl` registration and ~three pooled
//! buffers, nothing else.
//!
//! ## Architecture
//!
//! One blocking accept thread assigns connections round-robin to
//! loop shards. Each shard owns an epoll instance and a private
//! `fd → Conn` table; the accept thread hands streams over through a
//! mutexed inbox and registers the fd with the shard's epoll (safe
//! cross-thread by epoll's contract; the loop drains the inbox before
//! processing each wait batch, and level-triggered readiness re-fires
//! for anything that raced).
//!
//! Per connection the loop keeps three pooled, reusable buffers and a
//! FIFO of **response slots**:
//!
//! * `rbuf` — the read ring: raw bytes off the socket, consumed in
//!   place (v1 lines are scanned for `\n`; binary frames are decoded
//!   **zero-copy** via [`FrameRef`](crate::server::frame::FrameRef)
//!   straight out of this buffer — see [`super::tcp::frame_step`]).
//! * `wbuf` — the write ring: responses serialize into it
//!   ([`render_score_into`] appends binary frames without allocating)
//!   and it drains to the socket on writability.
//! * `dbuf` + `slots` — the ordering machinery: responses must leave in
//!   request order, so a control response that becomes ready while an
//!   earlier score is still being computed parks its bytes in `dbuf`
//!   behind a `Slot::Bytes` marker; `Slot::Pending` holds the worker's
//!   response receiver. The pump walks slots front-to-back and stops at
//!   the first unready pending — order is structural, not scheduled.
//!
//! ## Backpressure
//!
//! Two local conditions pause *reading* (the loop simply drops `EPOLLIN`
//! interest, so the kernel's TCP window throttles the client — no
//! thread ever blocks):
//!
//! * `slots` at `max_pending_per_conn` (the pipelining bound), or
//! * `wbuf` beyond a high-water mark (a slow consumer).
//!
//! Writability interest (`EPOLLOUT`) is armed exactly while `wbuf` has
//! unflushed bytes. Admission-queue overload is unchanged from the
//! thread backend: shed at the edge with an explicit `overloaded`
//! response.
//!
//! ## Wakeups
//!
//! Worker completions arrive on per-request mpsc receivers, which epoll
//! cannot watch directly. Each shard therefore registers an **eventfd**
//! ([`WakeFd`]) in its epoll set under a sentinel token; the coordinator
//! workers signal every shard's eventfd through the hub's
//! [`CompletionNotifier`](crate::coordinator::service::CompletionNotifier)
//! the moment a response is sent, so `epoll_wait` returns immediately
//! and the pump tick resolves the slot. With a wake fd installed the
//! loop waits up to [`IDLE_TICK_MS`] even while slots are outstanding
//! (the tick is only a lost-wakeup safety net); without one — the
//! legacy configuration — it falls back to polling at
//! [`ACTIVE_TICK_MS`] whenever any connection has outstanding slots.
//!
//! ## No mio?
//!
//! The crate is dependency-free by charter (see `Cargo.toml`), so the
//! epoll surface is declared directly in [`sys`] — three syscalls and a
//! struct, the subset mio itself sits on.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::service::ScoreResponse;
use crate::error::{Error, Result};
use crate::server::faultpoint;
use crate::server::tcp::{
    frame_step, json_step, render_batch_into, render_score_into, BatchSlot, Job, Shared, Step,
    Wire, WireClass,
};

/// Raw epoll FFI: the kernel ABI subset this backend needs. Linux only.
mod sys {
    use std::os::raw::{c_int, c_uint, c_void};

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;

    /// `struct epoll_event`. Packed on x86-64 (kernel ABI); natural
    /// alignment elsewhere.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
        pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }
}

/// Epoll token reserved for a shard's wake eventfd (never a valid
/// connection fd, which are nonnegative).
const WAKE_TOKEN: u64 = u64::MAX;

/// A nonblocking eventfd used to kick a loop shard out of `epoll_wait`
/// when a coordinator worker completes a request. Signaling from any
/// thread is a single 8-byte `write`; the owning shard drains the
/// counter on wake. The fd closes on drop.
pub(crate) struct WakeFd {
    fd: std::os::raw::c_int,
}

// Safety: the fd is only ever used via read/write/epoll syscalls,
// all of which are thread-safe on a shared descriptor.
unsafe impl Send for WakeFd {}
unsafe impl Sync for WakeFd {}

impl WakeFd {
    /// Create a fresh eventfd (counter 0, nonblocking, cloexec).
    pub(crate) fn new() -> Result<WakeFd> {
        let fd = unsafe { sys::eventfd(0, sys::EFD_NONBLOCK | sys::EFD_CLOEXEC) };
        if fd < 0 {
            return Err(Error::io("eventfd", std::io::Error::last_os_error()));
        }
        Ok(WakeFd { fd })
    }

    /// Wake the owning shard. Nonblocking: if the counter is already
    /// saturated the write fails with EAGAIN, which is fine — the fd is
    /// readable either way, so the wakeup is never lost.
    pub(crate) fn signal(&self) {
        let one: u64 = 1;
        unsafe {
            sys::write(self.fd, (&one as *const u64).cast(), 8);
        }
    }

    /// Reset the counter so level-triggered epoll stops reporting it.
    fn drain(&self) {
        let mut buf = 0u64;
        unsafe {
            sys::read(self.fd, (&mut buf as *mut u64).cast(), 8);
        }
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe { sys::close(self.fd) };
    }
}

/// Socket-read granularity.
const READ_CHUNK: usize = 16 * 1024;
/// Minimum bound on one v1 JSON line. The threads backend's `read_line`
/// accepts lines of any length, and big v1 lines are legitimate (a
/// `reload` carrying a wide ensemble snapshot); the event loop still
/// needs *some* bound to cap per-connection memory, so it uses
/// `max(max_frame_bytes, this)` and answers an over-limit line with a
/// structured error rather than a silent drop.
const V1_LINE_CAP_MIN: usize = 16 << 20;
/// Unflushed `wbuf` bytes beyond which the connection stops reading.
const WBUF_HIGH_WATER: usize = 256 * 1024;
/// Flushed-prefix size that triggers `wbuf` compaction.
const WBUF_COMPACT: usize = 64 * 1024;
/// Consumed-prefix size that triggers `rbuf` compaction.
const RBUF_COMPACT: usize = 16 * 1024;
/// Max events harvested per `epoll_wait`.
const MAX_EVENTS: usize = 256;
/// Wait bound while any connection has outstanding response slots.
const ACTIVE_TICK_MS: i32 = 1;
/// Wait bound while fully idle (also the shutdown-latency bound).
const IDLE_TICK_MS: i32 = 50;

/// One event-loop shard: an epoll instance plus the accept thread's
/// hand-off inbox, and optionally the wake eventfd the coordinator
/// workers signal on completion (see module docs, "Wakeups").
struct LoopShard {
    epfd: std::os::raw::c_int,
    inbox: Mutex<Vec<TcpStream>>,
    wake: Option<Arc<WakeFd>>,
}

// Safety: epfd is only ever passed to epoll syscalls, which are
// documented thread-safe; the inbox is mutexed.
impl Drop for LoopShard {
    fn drop(&mut self) {
        unsafe { sys::close(self.epfd) };
    }
}

/// In-order response slot (see module docs).
enum Slot {
    /// `len` bytes parked in `dbuf`, already counted against the wire
    /// stats at enqueue time.
    Bytes { len: usize },
    /// An admitted request awaiting its worker response.
    Pending { wire: Wire, rx: Receiver<ScoreResponse> },
    /// An admitted batch awaiting its worker responses: one receiver
    /// for the whole batch plus the decode-time per-example verdicts
    /// (see [`BatchSlot`]); renders as one response when ready.
    PendingBatch { wire: Wire, rx: Receiver<Vec<ScoreResponse>>, verdicts: Vec<BatchSlot> },
}

/// Per-connection state owned by exactly one loop shard.
struct Conn {
    stream: TcpStream,
    /// Read ring: bytes `[rstart..rbuf.len())` are unconsumed input.
    rbuf: Vec<u8>,
    rstart: usize,
    /// Write ring: bytes `[wstart..wbuf.len())` are unflushed output.
    wbuf: Vec<u8>,
    wstart: usize,
    /// Deferred-response bytes parked behind pendings (`[dstart..)`
    /// live), drained into `wbuf` by the pump in slot order.
    dbuf: Vec<u8>,
    dstart: usize,
    slots: VecDeque<Slot>,
    /// Negotiated binary framing (after a granted v2+ `hello`).
    binary: bool,
    /// Peer closed its write half (or read failed): no more input, but
    /// buffered requests still get answered — half-close works.
    read_closed: bool,
    /// Tear down once slots and `wbuf` drain; stop consuming input.
    closing: bool,
    /// Currently registered epoll interest mask.
    interest: u32,
    /// Membership flag for the shard's active (has-slots) list.
    active: bool,
    /// Last time bytes arrived from the peer; the idle sweep reaps
    /// connections past `idle_timeout_ms` (slowloris defense).
    last_activity: Instant,
}

impl Conn {
    fn new(stream: TcpStream, shared: &Shared) -> Conn {
        Conn {
            stream,
            rbuf: shared.pool.get(),
            rstart: 0,
            wbuf: shared.pool.get(),
            wstart: 0,
            dbuf: shared.pool.get(),
            dstart: 0,
            slots: VecDeque::new(),
            binary: false,
            read_closed: false,
            closing: false,
            interest: sys::EPOLLIN | sys::EPOLLRDHUP,
            active: false,
            last_activity: Instant::now(),
        }
    }

    fn wbuf_pending(&self) -> usize {
        self.wbuf.len() - self.wstart
    }

    fn rbuf_unconsumed(&self) -> usize {
        self.rbuf.len() - self.rstart
    }

    /// Reading is paused while local buffers are saturated — the kernel
    /// TCP window then backpressures the peer.
    fn read_paused(&self, shared: &Shared) -> bool {
        self.closing
            || self.read_closed
            || self.slots.len() >= shared.max_pending
            || self.wbuf_pending() > WBUF_HIGH_WATER
            || self.rbuf_unconsumed() > input_cap(shared) + 4
    }
}

/// Per-connection input-buffer bound: every legal binary frame fits
/// (`max_frame_bytes` + prefix), and v1 lines get at least
/// [`V1_LINE_CAP_MIN`] (the threads backend accepts unbounded lines;
/// see the constant's docs).
fn input_cap(shared: &Shared) -> usize {
    shared.max_frame_bytes.max(V1_LINE_CAP_MIN)
}

/// Running event backend: the accept thread plus the loop shards.
pub(crate) struct EventBackend {
    accept_join: JoinHandle<()>,
    loop_joins: Vec<JoinHandle<()>>,
}

impl EventBackend {
    /// Join everything. Call with `Shared::shutting_down` raised (the
    /// loops poll it at [`IDLE_TICK_MS`] granularity) and the accept
    /// thread woken; loops drain every admitted request before exiting.
    pub(crate) fn join(self) {
        let _ = self.accept_join.join();
        for join in self.loop_joins {
            let _ = join.join();
        }
    }
}

/// Spawn the backend: `event_threads` loop shards plus the accept
/// thread, all serving `shared`'s registry. `wake_fds` carries one
/// pre-created eventfd per shard (created before the registry so the
/// hubs' [`CompletionNotifier`](crate::coordinator::service::CompletionNotifier)
/// can already signal them); pass an empty vec to fall back to the
/// legacy 1 ms completion-poll tick.
pub(crate) fn spawn(
    listener: TcpListener,
    shared: Arc<Shared>,
    event_threads: usize,
    mut wake_fds: Vec<Arc<WakeFd>>,
) -> Result<EventBackend> {
    let mut shards = Vec::with_capacity(event_threads.max(1));
    for _ in 0..event_threads.max(1) {
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(Error::io("epoll_create1", std::io::Error::last_os_error()));
        }
        let wake = if wake_fds.is_empty() { None } else { Some(wake_fds.remove(0)) };
        if let Some(wake) = &wake {
            let mut ev = sys::EpollEvent { events: sys::EPOLLIN, data: WAKE_TOKEN };
            if unsafe { sys::epoll_ctl(epfd, sys::EPOLL_CTL_ADD, wake.fd, &mut ev) } < 0 {
                unsafe { sys::close(epfd) };
                return Err(Error::io("epoll_ctl(wake)", std::io::Error::last_os_error()));
            }
        }
        shards.push(Arc::new(LoopShard { epfd, inbox: Mutex::new(Vec::new()), wake }));
    }
    let mut loop_joins = Vec::with_capacity(shards.len());
    for shard in &shards {
        let shard = shard.clone();
        let shared = shared.clone();
        loop_joins.push(std::thread::spawn(move || run_loop(&shard, &shared)));
    }
    let accept_join = std::thread::spawn(move || accept_loop(listener, &shared, &shards));
    Ok(EventBackend { accept_join, loop_joins })
}

/// Blocking accept; round-robin shard assignment. Raises the shutdown
/// flag on exit so the loops always die with it.
fn accept_loop(listener: TcpListener, shared: &Arc<Shared>, shards: &[Arc<LoopShard>]) {
    let mut next = 0usize;
    for stream in listener.incoming() {
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        // Admission cap: accept-and-close beats a silently full backlog.
        if shared.live_conns.load(Ordering::Relaxed) >= shared.max_conns as u64 {
            drop(stream);
            continue;
        }
        if stream.set_nonblocking(true).is_err() {
            continue;
        }
        shared.accepted.fetch_add(1, Ordering::Relaxed);
        shared.live_conns.fetch_add(1, Ordering::Relaxed);
        let shard = &shards[next % shards.len()];
        next = next.wrapping_add(1);
        let fd = stream.as_raw_fd();
        // Inbox first, then register: the loop drains the inbox before
        // each event batch, and level-triggered epoll re-reports
        // anything that raced the hand-off.
        shard.inbox.lock().unwrap().push(stream);
        let mut ev =
            sys::EpollEvent { events: sys::EPOLLIN | sys::EPOLLRDHUP, data: fd as u64 };
        unsafe { sys::epoll_ctl(shard.epfd, sys::EPOLL_CTL_ADD, fd, &mut ev) };
    }
    shared.shutting_down.store(true, Ordering::SeqCst);
}

/// One shard's loop: adopt, wait, dispatch, pump, repeat — then drain.
fn run_loop(shard: &LoopShard, shared: &Shared) {
    let mut conns: HashMap<i32, Conn> = HashMap::new();
    // Connections with outstanding response slots, pumped every tick.
    let mut active: Vec<i32> = Vec::new();
    let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
    // Shared socket-read scratch: zero-initialized once, then only the
    // received bytes are ever copied out of it.
    let mut scratch = vec![0u8; READ_CHUNK];
    let mut last_sweep = Instant::now();
    loop {
        adopt(shard, shared, &mut conns);
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        // With a wake eventfd the workers interrupt the wait on every
        // completion, so outstanding slots don't force a short tick —
        // the remaining timeout is only a lost-wakeup/shutdown bound.
        let timeout = if active.is_empty() || shard.wake.is_some() {
            IDLE_TICK_MS
        } else {
            ACTIVE_TICK_MS
        };
        let n = unsafe {
            sys::epoll_wait(shard.epfd, events.as_mut_ptr(), events.len() as i32, timeout)
        };
        if n < 0 {
            if std::io::Error::last_os_error().kind() != std::io::ErrorKind::Interrupted {
                // A broken epoll fd would otherwise spin; bound it.
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            continue;
        }
        // Adopt again: a connection registered mid-wait may already
        // have an event in this very batch.
        adopt(shard, shared, &mut conns);
        for ev in &events[..n as usize] {
            // Copy out of the (possibly packed) struct before use.
            let data = ev.data;
            let mask = ev.events;
            if data == WAKE_TOKEN {
                // Worker-completion wakeup: reset the counter; the pump
                // tick below resolves whichever slots became ready.
                if let Some(wake) = &shard.wake {
                    wake.drain();
                }
                continue;
            }
            handle_event(&mut conns, &mut active, data as i32, mask, shard, shared, &mut scratch);
        }
        // Pump tick: revisit every connection with outstanding slots.
        let tick = std::mem::take(&mut active);
        for fd in tick {
            if let Some(conn) = conns.get_mut(&fd) {
                conn.active = false;
            } else {
                continue;
            }
            let dead = {
                let conn = conns.get_mut(&fd).expect("checked above");
                !service(conn, shard, shared, fd)
            };
            finish_or_requeue(&mut conns, &mut active, fd, dead, shared);
        }
        // Idle sweep (~1 s granularity): reap connections silent past
        // the deadline. Only truly quiescent ones — a connection still
        // owed a response, or with unflushed output, is never reaped,
        // so a deadline can't eat an admitted request's answer.
        if shared.idle_timeout_ms > 0 && last_sweep.elapsed().as_secs() >= 1 {
            last_sweep = Instant::now();
            let idle: Vec<i32> = conns
                .iter()
                .filter(|(_, c)| {
                    c.slots.is_empty()
                        && c.wbuf_pending() == 0
                        && c.last_activity.elapsed().as_millis() as u64 > shared.idle_timeout_ms
                })
                .map(|(&fd, _)| fd)
                .collect();
            for fd in idle {
                if let Some(conn) = conns.remove(&fd) {
                    close_conn(conn, shared);
                }
            }
        }
    }
    // Shutdown: every admitted request is still answered — the worker
    // generations stay alive until `TcpServer` joins this loop, so a
    // blocking drain terminates.
    adopt(shard, shared, &mut conns);
    for (_, conn) in conns.drain() {
        drain_and_close(conn, shared);
    }
}

/// Move accepted connections from the inbox into the shard's table.
fn adopt(shard: &LoopShard, shared: &Shared, conns: &mut HashMap<i32, Conn>) {
    let incoming: Vec<TcpStream> = std::mem::take(&mut *shard.inbox.lock().unwrap());
    for stream in incoming {
        let fd = stream.as_raw_fd();
        conns.insert(fd, Conn::new(stream, shared));
    }
}

/// Dispatch one epoll event for `fd`.
fn handle_event(
    conns: &mut HashMap<i32, Conn>,
    active: &mut Vec<i32>,
    fd: i32,
    mask: u32,
    shard: &LoopShard,
    shared: &Shared,
    scratch: &mut [u8],
) {
    let dead = {
        let Some(conn) = conns.get_mut(&fd) else { return };
        let mut dead = mask & sys::EPOLLERR != 0;
        if !dead && mask & (sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLHUP) != 0 {
            match read_some(conn, shared, scratch) {
                ReadOutcome::Progress => {}
                ReadOutcome::Eof => conn.read_closed = true,
                ReadOutcome::Fatal => dead = true,
            }
        }
        if !dead {
            dead = !service(conn, shard, shared, fd);
        }
        dead
    };
    finish_or_requeue(conns, active, fd, dead, shared);
}

/// Close a dead connection, or re-enter it on the active list while it
/// still owes responses.
fn finish_or_requeue(
    conns: &mut HashMap<i32, Conn>,
    active: &mut Vec<i32>,
    fd: i32,
    dead: bool,
    shared: &Shared,
) {
    if dead {
        if let Some(conn) = conns.remove(&fd) {
            close_conn(conn, shared);
        }
        return;
    }
    if let Some(conn) = conns.get_mut(&fd) {
        if !conn.slots.is_empty() && !conn.active {
            conn.active = true;
            active.push(fd);
        }
    }
}

/// Release a connection's pooled buffers and the live-conn slot.
/// Dropping the stream closes the fd, which deregisters it from epoll.
fn close_conn(conn: Conn, shared: &Shared) {
    shared.pool.put(conn.rbuf);
    shared.pool.put(conn.wbuf);
    shared.pool.put(conn.dbuf);
    shared.live_conns.fetch_sub(1, Ordering::Relaxed);
}

enum ReadOutcome {
    Progress,
    Eof,
    Fatal,
}

/// Pull whatever the socket has into `rbuf`, up to the pause bound.
/// Reads land in the shard's reusable `scratch` and only the bytes
/// actually received are copied on — no per-read zeroing of the chunk.
fn read_some(conn: &mut Conn, shared: &Shared, scratch: &mut [u8]) -> ReadOutcome {
    loop {
        if conn.read_paused(shared) {
            return ReadOutcome::Progress;
        }
        match conn.stream.read(scratch) {
            Ok(0) => return ReadOutcome::Eof,
            Ok(n) => {
                conn.last_activity = Instant::now();
                conn.rbuf.extend_from_slice(&scratch[..n]);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return ReadOutcome::Progress;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Fatal,
        }
    }
}

/// Drive one connection as far as it can go right now: resolve ready
/// slots, consume buffered input, flush, and retune epoll interest.
/// Returns `false` once the connection should be closed.
fn service(conn: &mut Conn, shard: &LoopShard, shared: &Shared, fd: i32) -> bool {
    pump(conn, shared);
    if !conn.closing {
        let paused = process_input(conn, shared);
        pump(conn, shared);
        if conn.read_closed && !paused && !conn.closing {
            // Input is exhausted and no more will ever arrive. A
            // leftover tail gets the threads backend's treatment first
            // (final unterminated v1 line is processed; a partial
            // binary frame draws BAD_FRAME); whatever is in flight
            // still answers, then the connection ends.
            if conn.rbuf_unconsumed() > 0 {
                finish_partial_input(conn, shared);
            }
            conn.closing = true;
        }
    }
    compact_rbuf(conn);
    if !flush(conn) {
        return false;
    }
    if (conn.closing || (conn.read_closed && conn.rbuf_unconsumed() == 0))
        && conn.slots.is_empty()
        && conn.wbuf_pending() == 0
    {
        return false;
    }
    update_interest(conn, shard, shared, fd);
    true
}

/// Walk the slot FIFO front-to-back, moving everything ready into
/// `wbuf`; stops at the first pending whose worker hasn't answered.
fn pump(conn: &mut Conn, shared: &Shared) {
    loop {
        let Some(front) = conn.slots.front_mut() else { break };
        match front {
            Slot::Bytes { len } => {
                let len = *len;
                conn.wbuf.extend_from_slice(&conn.dbuf[conn.dstart..conn.dstart + len]);
                conn.dstart += len;
                conn.slots.pop_front();
            }
            Slot::Pending { wire, rx } => {
                let resp = match rx.try_recv() {
                    Ok(resp) => Some(resp),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => None,
                };
                let before = conn.wbuf.len();
                render_score_into(wire, resp, &mut conn.wbuf);
                let counters = shared.wire(wire.class());
                counters.bytes.fetch_add((conn.wbuf.len() - before) as u64, Ordering::Relaxed);
                counters.served.fetch_add(1, Ordering::Relaxed);
                conn.slots.pop_front();
            }
            Slot::PendingBatch { wire, rx, verdicts } => {
                let results = match rx.try_recv() {
                    Ok(results) => Some(results),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => None,
                };
                let before = conn.wbuf.len();
                render_batch_into(wire, verdicts, results, &mut conn.wbuf);
                let counters = shared.wire(wire.class());
                counters.bytes.fetch_add((conn.wbuf.len() - before) as u64, Ordering::Relaxed);
                // One per example: batch and single traffic count on
                // the same served scale.
                counters.served.fetch_add(verdicts.len() as u64, Ordering::Relaxed);
                conn.slots.pop_front();
            }
        }
    }
    if conn.slots.is_empty() {
        conn.dbuf.clear();
        conn.dstart = 0;
    }
}

/// Outcome of trying to carve one message out of the read buffer.
enum Parsed {
    /// Not enough bytes yet.
    NeedMore,
    /// `n` bytes consumed, nothing to do (blank line).
    Skip(usize),
    /// `n` bytes consumed, handle `step`.
    Msg(usize, Step),
}

/// Consume as many buffered requests as backpressure allows. Returns
/// `true` when it stopped because the connection is paused (slots or
/// write buffer saturated), `false` when it ran out of input.
fn process_input(conn: &mut Conn, shared: &Shared) -> bool {
    loop {
        if conn.closing {
            return false;
        }
        if conn.slots.len() >= shared.max_pending || conn.wbuf_pending() > WBUF_HIGH_WATER {
            return true;
        }
        // Detach the read buffer so the borrowed parse (`FrameRef`
        // slices into it) can coexist with slot/wbuf mutation. O(1).
        let rbuf = std::mem::take(&mut conn.rbuf);
        let input = &rbuf[conn.rstart..];
        let parsed =
            if conn.binary { parse_frame(input, shared) } else { parse_line(input, shared) };
        let outcome = match parsed {
            Parsed::NeedMore => None,
            Parsed::Skip(n) => Some((n, None)),
            Parsed::Msg(n, step) => Some((n, Some(step))),
        };
        conn.rbuf = rbuf;
        match outcome {
            None => return false,
            Some((n, step)) => {
                conn.rstart += n;
                if let Some(step) = step {
                    apply_step(conn, step, shared);
                }
            }
        }
    }
}

/// v1 mode: carve one `\n`-terminated JSON line.
fn parse_line(input: &[u8], shared: &Shared) -> Parsed {
    match input.iter().position(|&b| b == b'\n') {
        None => {
            // A line beyond the (generous) cap is answered with a
            // structured error, then the connection closes — memory
            // stays bounded and the client learns why.
            if input.len() > input_cap(shared) {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let resp = crate::server::protocol::Response::Error {
                    id: None,
                    error: format!("line exceeds server cap {}", input_cap(shared)),
                    retryable: false,
                };
                return Parsed::Msg(
                    input.len(),
                    Step::JobThenClose(Job::Bytes(
                        resp.to_line().into_bytes(),
                        WireClass::V1,
                    )),
                );
            }
            Parsed::NeedMore
        }
        Some(pos) => {
            let consumed = pos + 1;
            match std::str::from_utf8(&input[..pos]) {
                // The thread backend's read_line fails the same way on
                // invalid UTF-8: the connection ends.
                Err(_) => Parsed::Msg(consumed, Step::Close),
                Ok(line) => {
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        Parsed::Skip(consumed)
                    } else {
                        Parsed::Msg(consumed, json_step(trimmed, shared))
                    }
                }
            }
        }
    }
}

/// Binary mode: carve one length-prefixed frame and run the shared
/// zero-copy dispatch ([`frame_step`]) on its body in place.
fn parse_frame(input: &[u8], shared: &Shared) -> Parsed {
    if input.len() < 4 {
        return Parsed::NeedMore;
    }
    let len = u32::from_le_bytes(input[0..4].try_into().unwrap()) as usize;
    if len > shared.max_frame_bytes || len == 0 {
        // Framing is lost; mirror the thread backend's read-path error
        // (one BAD_FRAME response, then close). The rest of the buffer
        // is garbage by definition, so consume it all.
        shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
        let detail = if len == 0 {
            crate::server::frame::FrameError::Empty
        } else {
            crate::server::frame::FrameError::TooLarge { len, max: shared.max_frame_bytes }
        };
        let frame = crate::server::frame::Frame::Error {
            code: crate::server::frame::ErrorCode::BadFrame,
            retryable: false,
            msg: detail.to_string(),
        };
        return Parsed::Msg(
            input.len(),
            Step::JobThenClose(Job::Bytes(frame.encode(), WireClass::V2Binary)),
        );
    }
    if input.len() < 4 + len {
        return Parsed::NeedMore;
    }
    Parsed::Msg(4 + len, frame_step(&input[4..4 + len], shared))
}

/// Consume the input tail left when the peer closed mid-message,
/// mirroring the threads backend: `BufRead::read_line` hands its
/// caller a final unterminated line at EOF (so the event loop processes
/// it too), and a partial binary frame is a truncated stream answered
/// with `BAD_FRAME` (what `Frame::read_body`'s failing `read_exact`
/// produces over there).
fn finish_partial_input(conn: &mut Conn, shared: &Shared) {
    let rbuf = std::mem::take(&mut conn.rbuf);
    if conn.binary {
        shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
        let frame = crate::server::frame::Frame::Error {
            code: crate::server::frame::ErrorCode::BadFrame,
            retryable: false,
            msg: "truncated frame: connection closed mid-frame".into(),
        };
        apply_step(
            conn,
            Step::JobThenClose(Job::Bytes(frame.encode(), WireClass::V2Binary)),
            shared,
        );
    } else {
        let step = match std::str::from_utf8(&rbuf[conn.rstart..]) {
            Ok(line) if !line.trim().is_empty() => Some(json_step(line.trim(), shared)),
            _ => None,
        };
        if let Some(step) = step {
            apply_step(conn, step, shared);
        }
    }
    conn.rstart = rbuf.len();
    conn.rbuf = rbuf;
}

/// Enqueue one reader verdict into the connection's ordered output.
fn apply_step(conn: &mut Conn, step: Step, shared: &Shared) {
    match step {
        Step::Job(job) => apply_job(conn, job, shared),
        Step::JobThenBinary(job) => {
            apply_job(conn, job, shared);
            conn.binary = true;
        }
        Step::JobThenClose(job) => {
            apply_job(conn, job, shared);
            conn.closing = true;
        }
        Step::Close => conn.closing = true,
    }
}

fn apply_job(conn: &mut Conn, job: Job, shared: &Shared) {
    match job {
        Job::Bytes(bytes, class) => {
            shared.wire(class).bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
            if conn.slots.is_empty() {
                // Nothing ahead of it: straight to the write ring.
                conn.wbuf.extend_from_slice(&bytes);
            } else {
                // Park behind the outstanding pendings to keep request
                // order; the pump releases it.
                conn.dbuf.extend_from_slice(&bytes);
                conn.slots.push_back(Slot::Bytes { len: bytes.len() });
            }
        }
        Job::Pending { wire, rx } => conn.slots.push_back(Slot::Pending { wire, rx }),
        Job::PendingBatch { wire, rx, slots } => {
            conn.slots.push_back(Slot::PendingBatch { wire, rx, verdicts: slots })
        }
    }
}

/// Reclaim the consumed prefix of the read ring (capacity retained).
fn compact_rbuf(conn: &mut Conn) {
    if conn.rstart == conn.rbuf.len() {
        conn.rbuf.clear();
        conn.rstart = 0;
    } else if conn.rstart >= RBUF_COMPACT {
        conn.rbuf.copy_within(conn.rstart.., 0);
        let remaining = conn.rbuf.len() - conn.rstart;
        conn.rbuf.truncate(remaining);
        conn.rstart = 0;
    }
}

/// Nonblocking drain of the write ring. Returns `false` on a dead peer.
fn flush(conn: &mut Conn) -> bool {
    if conn.wstart < conn.wbuf.len() {
        faultpoint::maybe_delay();
        if faultpoint::fires(faultpoint::Point::TornWrite) {
            // Crash the connection mid-response: emit a prefix of the
            // pending bytes, then report the peer dead so the caller
            // tears the connection down — the client must spot the
            // truncated frame and reconnect.
            let pending = &conn.wbuf[conn.wstart..];
            let _ = conn.stream.write(&pending[..pending.len() / 2]);
            return false;
        }
    }
    while conn.wstart < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wstart..]) {
            Ok(0) => return false,
            Ok(n) => conn.wstart += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
    if conn.wstart == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wstart = 0;
    } else if conn.wstart >= WBUF_COMPACT {
        conn.wbuf.copy_within(conn.wstart.., 0);
        let remaining = conn.wbuf.len() - conn.wstart;
        conn.wbuf.truncate(remaining);
        conn.wstart = 0;
    }
    true
}

/// Retune epoll interest to the connection's current needs: reads
/// while not paused, writability exactly while output is pending.
fn update_interest(conn: &mut Conn, shard: &LoopShard, shared: &Shared, fd: i32) {
    let mut desired = 0u32;
    if !conn.read_paused(shared) {
        desired |= sys::EPOLLIN | sys::EPOLLRDHUP;
    }
    if conn.wbuf_pending() > 0 {
        desired |= sys::EPOLLOUT;
    }
    if desired != conn.interest {
        let mut ev = sys::EpollEvent { events: desired, data: fd as u64 };
        unsafe { sys::epoll_ctl(shard.epfd, sys::EPOLL_CTL_MOD, fd, &mut ev) };
        conn.interest = desired;
    }
}

/// Shutdown-path teardown: resolve every outstanding slot (blocking on
/// the still-live workers), best-effort **bounded** write, release.
fn drain_and_close(mut conn: Conn, shared: &Shared) {
    while let Some(slot) = conn.slots.pop_front() {
        match slot {
            Slot::Bytes { len } => {
                conn.wbuf.extend_from_slice(&conn.dbuf[conn.dstart..conn.dstart + len]);
                conn.dstart += len;
            }
            Slot::Pending { wire, rx } => {
                let resp = rx.recv().ok();
                let before = conn.wbuf.len();
                render_score_into(&wire, resp, &mut conn.wbuf);
                let counters = shared.wire(wire.class());
                counters.bytes.fetch_add((conn.wbuf.len() - before) as u64, Ordering::Relaxed);
                counters.served.fetch_add(1, Ordering::Relaxed);
            }
            Slot::PendingBatch { wire, rx, verdicts } => {
                let results = rx.recv().ok();
                let before = conn.wbuf.len();
                render_batch_into(&wire, &verdicts, results, &mut conn.wbuf);
                let counters = shared.wire(wire.class());
                counters.bytes.fetch_add((conn.wbuf.len() - before) as u64, Ordering::Relaxed);
                counters.served.fetch_add(verdicts.len() as u64, Ordering::Relaxed);
            }
        }
    }
    // Bounded flush: a peer that stopped reading (full receive window)
    // must not be able to hang server shutdown — the write timeout
    // errors out of `write_all`, and whatever didn't fit is abandoned
    // with the connection. (The threads backend gets the same property
    // from teardown_connections' socket shutdown.)
    let _ = conn.stream.set_nonblocking(false);
    if shared.write_timeout_ms > 0 {
        let _ = conn
            .stream
            .set_write_timeout(Some(std::time::Duration::from_millis(shared.write_timeout_ms)));
    }
    let _ = conn.stream.write_all(&conn.wbuf[conn.wstart..]);
    close_conn(conn, shared);
}
