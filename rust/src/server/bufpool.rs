//! [`BufPool`]: a bounded pool of reusable byte buffers for the
//! serving transport.
//!
//! Every connection needs read/write/deferred buffers, and every
//! response needs somewhere to serialize. Allocating those per
//! connection (or worse, per response) puts the allocator on the hot
//! path; the pool recycles them instead, so at steady state a
//! connection churn or a response burst touches no allocator at all.
//! Both transport backends use it: the event loop checks buffers out at
//! accept and back in at close, and the thread backend's writer uses a
//! pooled scratch buffer for response rendering.
//!
//! The pool is deliberately simple — a mutex around a stack of `Vec`s —
//! because checkouts happen per *connection*, not per request: the
//! per-request path works entirely on buffers the connection already
//! owns. Two bounds keep it honest under adversarial load:
//!
//! * at most `max_pooled` buffers are retained (extras are dropped, not
//!   hoarded), and
//! * a returned buffer whose capacity grew beyond `max_retained_cap`
//!   (e.g. after one giant JSON stats response) is dropped rather than
//!   pinned in memory forever.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A bounded pool of reusable `Vec<u8>` buffers.
pub struct BufPool {
    bufs: Mutex<Vec<Vec<u8>>>,
    max_pooled: usize,
    max_retained_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Pool statistics (observability for the allocation-free claim).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufPoolStats {
    /// Checkouts served from the pool.
    pub hits: u64,
    /// Checkouts that had to allocate a fresh buffer.
    pub misses: u64,
    /// Buffers currently parked in the pool.
    pub pooled: usize,
}

impl BufPool {
    /// Pool retaining at most `max_pooled` buffers, each of at most
    /// `max_retained_cap` bytes capacity.
    pub fn new(max_pooled: usize, max_retained_cap: usize) -> Self {
        Self {
            bufs: Mutex::new(Vec::new()),
            max_pooled,
            max_retained_cap,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Defaults sized for the serving front-end: enough parked buffers
    /// to absorb connection churn, capped at 256 KiB capacity each
    /// (worst-case pool footprint 64 MiB; the rare buffer grown past
    /// the cap by a giant frame is dropped rather than pinned).
    pub fn serving_default() -> Self {
        Self::new(256, 1 << 18)
    }

    /// Check a buffer out: recycled if available (cleared, capacity
    /// intact), freshly allocated otherwise.
    pub fn get(&self) -> Vec<u8> {
        let recycled = self.bufs.lock().unwrap().pop();
        match recycled {
            Some(mut buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Return a buffer. Dropped instead of pooled when the pool is full
    /// or the buffer outgrew the retention cap.
    pub fn put(&self, buf: Vec<u8>) {
        if buf.capacity() == 0 || buf.capacity() > self.max_retained_cap {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.max_pooled {
            bufs.push(buf);
        }
    }

    /// Current counters.
    pub fn stats(&self) -> BufPoolStats {
        BufPoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            pooled: self.bufs.lock().unwrap().len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_capacity_and_counts_hits() {
        let pool = BufPool::new(4, 1 << 16);
        let mut a = pool.get();
        a.extend_from_slice(&[1, 2, 3, 4]);
        let cap = a.capacity();
        assert!(cap >= 4);
        pool.put(a);
        let b = pool.get();
        assert!(b.is_empty(), "recycled buffers come back cleared");
        assert_eq!(b.capacity(), cap, "capacity survives the round trip");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        pool.put(b);
        assert_eq!(pool.stats().pooled, 1);
    }

    #[test]
    fn bounds_are_enforced() {
        let pool = BufPool::new(2, 64);
        // Over-capacity buffers are dropped, not retained.
        pool.put(Vec::with_capacity(1024));
        assert_eq!(pool.stats().pooled, 0);
        // Zero-capacity buffers are not worth pooling.
        pool.put(Vec::new());
        assert_eq!(pool.stats().pooled, 0);
        // The pool never holds more than max_pooled.
        for _ in 0..5 {
            pool.put(Vec::with_capacity(16));
        }
        assert_eq!(pool.stats().pooled, 2);
    }

    #[test]
    fn concurrent_checkouts_are_safe() {
        let pool = std::sync::Arc::new(BufPool::serving_default());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let pool = pool.clone();
                scope.spawn(move || {
                    for i in 0..200 {
                        let mut buf = pool.get();
                        buf.extend_from_slice(&[i as u8; 32]);
                        pool.put(buf);
                    }
                });
            }
        });
        let s = pool.stats();
        assert_eq!(s.hits + s.misses, 800);
        assert!(s.pooled <= 256);
    }
}
