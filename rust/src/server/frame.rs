//! Protocol v2 binary frame codec.
//!
//! After a `{"op":"hello","proto":2}` handshake (see
//! [`crate::server::protocol`]) a connection switches to length-prefixed
//! binary frames in both directions. Every frame is
//!
//! ```text
//! len:u32 LE | op:u8 | payload (len - 1 bytes)
//! ```
//!
//! with all integers little-endian and floats IEEE-754 f64 LE. Ops:
//!
//! ```text
//! 0x01 SCORE_SPARSE     req   gen:u32 nnz:u16 then nnz × (idx:u16 val:f64)
//! 0x02 JSON_REQ         req   UTF-8 JSON body (any v1 request document)
//! 0x03 SCORE_DENSE      req   model:u16 gen:u32 count:u32 then count × f64   (v3)
//! 0x04 SCORE_SPARSE2    req   model:u16 gen:u32 nnz:u32 then nnz × (idx:u32 val:f64)  (v3)
//! 0x05 CLASSIFY_SPARSE  req   model:u16 gen:u32 nnz:u32 then nnz × (idx:u32 val:f64)  (v3)
//! 0x06 CLASSIFY_SPARSE_VERBOSE  req  same payload as 0x05; answered by 0x85  (v3)
//! 0x07 LEARN_SPARSE     req   model:u16 label:i8(±1) nnz:u32 then nnz × (idx:u32 val:f64)  (v4)
//! 0x08 SCORE_BATCH      req   model:u16 gen:u32 count:u16 then count ×
//!                             (nnz:u32 then nnz × (idx:u32 val:f64))  (v6)
//! 0x09 SCORE_SPARSE_EX  req   model:u16 gen:u32 deadline_ms:u32 lane:u8 nnz:u32
//!                             then nnz × (idx:u32 val:f64)  (v7)
//! 0x0A SCORE_BATCH_EX   req   model:u16 gen:u32 deadline_ms:u32 lane:u8 count:u16
//!                             then count × (nnz:u32 then nnz × (idx:u32 val:f64))  (v7)
//! 0x81 SCORE            resp  gen:u32 evaluated:u32 score:f64
//! 0x82 ERROR            resp  code:u8 retryable:u8 msg_len:u16 msg bytes
//! 0x83 JSON_RESP        resp  UTF-8 JSON body (any v1 response document)
//! 0x84 CLASS            resp  gen:u32 label:i64 votes:u32 voters:u32 evaluated:u32  (v3)
//! 0x85 CLASS_VERBOSE    resp  CLASS fields, then count:u32 then
//!                             count × (pos:i64 neg:i64 vote:i64 features:u32)  (v3)
//! 0x86 LEARN_ACK        resp  gen:u32 seen:u64  (v4)
//! 0x87 SCORE_BATCH_RESP resp  gen:u32 count:u16 then count ×
//!                             (status:u8 evaluated:u32 score:f64)  (v6)
//! 0x88 SCORE_EX         resp  gen:u32 flags:u8 evaluated:u32 score:f64  (v7)
//! 0x89 SCORE_BATCH_RESP_EX  resp  gen:u32 flags:u8 count:u16 then count ×
//!                             (status:u8 evaluated:u32 score:f64)  (v7)
//! ```
//!
//! ## Zero-copy decode
//!
//! [`Frame::decode_body`] materializes owned vectors — the right shape
//! for clients and tests. The server's hot path uses
//! [`FrameRef::decode_borrowed`] instead: it parses a frame body into
//! borrowed byte slices (`pairs`/`vals` pointing straight into the
//! connection's read buffer), the structural screens
//! ([`validate_pairs_u32`] and friends) walk those slices in place, and
//! nothing is allocated until the request is actually admitted
//! ([`pairs_to_features_u32`]). Symmetrically, [`Frame::encode_into`]
//! and the `put_*` slice encoders serialize into a caller-supplied
//! (reusable, pooled) buffer, so the transport's steady-state score
//! path performs no per-request heap allocation — see
//! `rust/tests/transport_alloc.rs` for the counting-allocator proof.
//!
//! `SCORE_SPARSE` is the hot path: a sparse example at MNIST density
//! (~150 nonzeros) costs ~1.5 KB on the wire instead of ~9 KB of dense
//! JSON, and decoding is a single pass with zero allocation-per-token —
//! the transport gets as sparse and as fast as the attentive evaluator.
//! `JSON_REQ`/`JSON_RESP` envelope the v1 JSON documents so control ops
//! (stats, reload, ping, dense scores) keep working after the switch
//! without a second codec.
//!
//! The protocol-v3 ops add **model routing** (the interned `u16` shard
//! id assigned by [`crate::server::registry::ModelRegistry`], 0 = the
//! default shard) and lift the legacy sparse frame's `u16` index bound
//! to `u32`. `SCORE_DENSE` extends the binary-framing win to non-sparse
//! workloads (embeddings, normalized inputs); `CLASSIFY_SPARSE` runs
//! the attentive all-pairs vote on an ensemble shard and is answered by
//! a `CLASS` frame. The server decodes the v3 ops on any binary
//! connection; clients send them only after `hello {"proto":3}` is
//! granted (the legacy `SCORE_SPARSE` keeps decoding forever, routed to
//! the default shard).
//!
//! The protocol-v4 op closes the train→serve loop: `LEARN_SPARSE`
//! submits one labeled example (`label` is ±1 on the wire) to the
//! routed shard's online trainer, which periodically publishes fresh
//! snapshot generations into the same hub the score path serves from.
//! Accepted examples are answered with `LEARN_ACK` carrying the shard's
//! *current serving* generation and the cumulative accepted-example
//! count; a full learn queue sheds with a retryable
//! [`ErrorCode::Overloaded`], and a shard with no trainer attached
//! answers a non-retryable [`ErrorCode::WrongModel`]. Clients send
//! `LEARN_SPARSE` only after `hello {"proto":4}` is granted.
//!
//! Protocol v5 adds no new frame ops — it is a **capability grant** for
//! the runtime shard-lifecycle control ops (`add-model` /
//! `remove-model`), which travel as `JSON_REQ`/`JSON_RESP` envelopes on
//! binary connections and as plain JSON lines on v1 connections. It
//! does add three error codes: a duplicate registration answers
//! [`ErrorCode::ModelExists`], naming a shard that is still draining
//! out answers the retryable [`ErrorCode::ModelBusy`], and removing the
//! default shard answers [`ErrorCode::DefaultModel`]. Scoring a shard
//! whose removal has already unpublished it answers the plain
//! non-retryable [`ErrorCode::UnknownModel`], exactly as if it had
//! never existed.
//!
//! The protocol-v6 ops amortize per-request transport overhead:
//! `SCORE_BATCH` carries up to the server's advertised
//! `max_batch_examples` sparse examples in one frame, routed to one
//! shard under one generation pin. The whole batch is admitted as a
//! single queue slot (one worker wakeup, one response frame), and the
//! examples are scored back-to-back in submission order, so a batch is
//! bit-identical to the same examples sent as single `SCORE_SPARSE2`
//! frames. Whole-batch failures (unknown model, wrong kind, stale pin,
//! overload) answer with one `ERROR` frame; anything per-example —
//! a dimension overrun, a structurally invalid example — degrades to a
//! per-example `status` byte in the `SCORE_BATCH_RESP` row (0 = OK,
//! else the [`ErrorCode`] wire byte), so one bad example never poisons
//! its batchmates. Clients send `SCORE_BATCH` only after
//! `hello {"proto":6}` is granted.
//!
//! The protocol-v7 ops carry the overload-brownout admission fields.
//! `SCORE_SPARSE_EX` / `SCORE_BATCH_EX` extend their v3/v6 twins with a
//! `deadline_ms:u32` relative deadline (0 = none; work still queued
//! past it is answered with the retryable [`ErrorCode::DeadlineExceeded`]
//! at dequeue instead of being scored) and a `lane:u8` admission-lane
//! override ([`LANE_DEFAULT`] / [`LANE_INTERACTIVE`] / [`LANE_BULK`]).
//! They are answered by `SCORE_EX` / `SCORE_BATCH_RESP_EX`, which add a
//! `flags:u8` ([`FLAG_DEGRADED`] marks a response scored under a
//! brownout tier with tightened early-exit thresholds). The legacy ops
//! keep their legacy responses byte-for-byte, so pre-v7 clients are
//! unaffected. Clients send the EX ops only after `hello {"proto":7}`
//! is granted.
//!
//! A `gen` of 0 in a request means "any model generation"; a nonzero
//! value pins the request to that generation and the server sheds it
//! with a retryable [`ErrorCode::StaleGeneration`] if a hot reload has
//! moved on. Responses carry the generation that actually served them.

use std::io::Read;

use crate::coordinator::service::{Features, VoterVote};

/// Structured error codes carried by `ERROR` frames (`0x82`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// The frame could not be decoded (bad op, bad lengths). Fatal for
    /// the connection: a binary stream cannot resync after framing loss.
    BadFrame = 1,
    /// Admission queue full; retry after backing off.
    Overloaded = 2,
    /// Payload dimensionality does not fit the serving model.
    DimMismatch = 3,
    /// A feature value was NaN or infinite.
    NonFinite = 4,
    /// The worker generation died before answering (shutdown race).
    Unavailable = 5,
    /// The request pinned a model generation that has been reloaded away.
    StaleGeneration = 6,
    /// Structurally invalid request (unsorted indices, bad JSON, ...).
    BadRequest = 7,
    /// The request named a model shard the registry does not hold.
    UnknownModel = 8,
    /// The op does not match the routed shard's model kind (`score` on
    /// an ensemble shard, `classify` on a binary one).
    WrongModel = 9,
    /// An `add-model` named a shard that is already registered.
    ModelExists = 10,
    /// The shard is mid-removal (draining); retry after the old name
    /// has fully retired.
    ModelBusy = 11,
    /// A `remove-model` named the default shard (id 0), which anchors
    /// legacy unrouted traffic and cannot be retired.
    DefaultModel = 12,
    /// The server failed internally while evaluating this request
    /// (worker panic, contained by `catch_unwind`). The request itself
    /// was well-formed and the worker has been respawned — retry.
    Internal = 13,
    /// The request's deadline had already expired when a worker dequeued
    /// it, so it was shed unscored (the answer would have arrived too
    /// late to be useful). Retryable: a fresh request with a fresh
    /// deadline can succeed once the queue drains.
    DeadlineExceeded = 14,
}

impl ErrorCode {
    /// Parse the wire byte.
    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        match b {
            1 => Some(ErrorCode::BadFrame),
            2 => Some(ErrorCode::Overloaded),
            3 => Some(ErrorCode::DimMismatch),
            4 => Some(ErrorCode::NonFinite),
            5 => Some(ErrorCode::Unavailable),
            6 => Some(ErrorCode::StaleGeneration),
            7 => Some(ErrorCode::BadRequest),
            8 => Some(ErrorCode::UnknownModel),
            9 => Some(ErrorCode::WrongModel),
            10 => Some(ErrorCode::ModelExists),
            11 => Some(ErrorCode::ModelBusy),
            12 => Some(ErrorCode::DefaultModel),
            13 => Some(ErrorCode::Internal),
            14 => Some(ErrorCode::DeadlineExceeded),
            _ => None,
        }
    }

    /// Does retrying later have a chance of succeeding?
    pub fn retryable(self) -> bool {
        matches!(
            self,
            ErrorCode::Overloaded
                | ErrorCode::Unavailable
                | ErrorCode::StaleGeneration
                | ErrorCode::ModelBusy
                | ErrorCode::Internal
                | ErrorCode::DeadlineExceeded
        )
    }

    /// Stable kebab-case name (used in JSON error strings and docs).
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad-frame",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::DimMismatch => "dimension-mismatch",
            ErrorCode::NonFinite => "non-finite",
            ErrorCode::Unavailable => "unavailable",
            ErrorCode::StaleGeneration => "stale-generation",
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::UnknownModel => "unknown-model",
            ErrorCode::WrongModel => "wrong-model-kind",
            ErrorCode::ModelExists => "model-exists",
            ErrorCode::ModelBusy => "model-busy",
            ErrorCode::DefaultModel => "default-model",
            ErrorCode::Internal => "internal",
            ErrorCode::DeadlineExceeded => "deadline-exceeded",
        }
    }
}

/// Why a frame failed to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The peer closed the stream cleanly between frames.
    Eof,
    /// The stream ended (or errored) mid-frame.
    Truncated(String),
    /// The length prefix exceeds the configured cap.
    TooLarge {
        /// Declared body length.
        len: usize,
        /// Configured maximum.
        max: usize,
    },
    /// Zero-length frame (no op byte).
    Empty,
    /// Unknown op byte.
    BadOp(u8),
    /// The payload does not match the op's declared layout — e.g. an
    /// `nnz` announcing more pairs than the frame carries.
    BadLayout(String),
    /// A JSON envelope payload was not valid UTF-8.
    BadUtf8,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Eof => write!(f, "connection closed"),
            FrameError::Truncated(detail) => write!(f, "truncated frame: {detail}"),
            FrameError::TooLarge { len, max } => {
                write!(f, "frame length {len} exceeds cap {max}")
            }
            FrameError::Empty => write!(f, "empty frame"),
            FrameError::BadOp(op) => write!(f, "unknown frame op {op:#04x}"),
            FrameError::BadLayout(detail) => write!(f, "bad frame layout: {detail}"),
            FrameError::BadUtf8 => write!(f, "JSON envelope is not UTF-8"),
        }
    }
}

/// Op byte: sparse score request (legacy u16 indices, default shard).
pub const OP_SCORE_SPARSE: u8 = 0x01;
/// Op byte: JSON-enveloped request.
pub const OP_JSON_REQ: u8 = 0x02;
/// Op byte: dense score request (v3; model-routed, f64-LE payload).
pub const OP_SCORE_DENSE: u8 = 0x03;
/// Op byte: sparse score request (v3; model-routed, u32 indices).
pub const OP_SCORE_SPARSE2: u8 = 0x04;
/// Op byte: sparse classify request (v3; model-routed all-pairs vote).
pub const OP_CLASSIFY_SPARSE: u8 = 0x05;
/// Op byte: sparse classify request with per-voter breakdown (v3).
pub const OP_CLASSIFY_SPARSE_VERBOSE: u8 = 0x06;
/// Op byte: sparse learn request (v4; model-routed labeled example).
pub const OP_LEARN_SPARSE: u8 = 0x07;
/// Op byte: batched sparse score request (v6; model-routed).
pub const OP_SCORE_BATCH: u8 = 0x08;
/// Op byte: sparse score request with admission extensions (v7;
/// deadline + lane; answered by `SCORE_EX`).
pub const OP_SCORE_SPARSE_EX: u8 = 0x09;
/// Op byte: batched sparse score request with admission extensions
/// (v7; answered by `SCORE_BATCH_RESP_EX`).
pub const OP_SCORE_BATCH_EX: u8 = 0x0A;
/// Op byte: score response.
pub const OP_SCORE: u8 = 0x81;
/// Op byte: error response.
pub const OP_ERROR: u8 = 0x82;
/// Op byte: JSON-enveloped response.
pub const OP_JSON_RESP: u8 = 0x83;
/// Op byte: classify response (v3).
pub const OP_CLASS: u8 = 0x84;
/// Op byte: classify response with per-voter breakdown (v3).
pub const OP_CLASS_VERBOSE: u8 = 0x85;
/// Op byte: learn acknowledgement (v4).
pub const OP_LEARN_ACK: u8 = 0x86;
/// Op byte: batched score response (v6).
pub const OP_SCORE_BATCH_RESP: u8 = 0x87;
/// Op byte: score response with flags (v7; answers `SCORE_SPARSE_EX`).
pub const OP_SCORE_EX: u8 = 0x88;
/// Op byte: batched score response with flags (v7; answers
/// `SCORE_BATCH_EX`).
pub const OP_SCORE_BATCH_RESP_EX: u8 = 0x89;

/// The `status` byte of an OK `SCORE_BATCH_RESP` row. Any other value
/// is the [`ErrorCode`] wire byte describing why that one example was
/// not scored (its batchmates are unaffected).
pub const BATCH_STATUS_OK: u8 = 0;

/// `flags` bit of the v7 EX responses: the answer was produced under a
/// brownout tier (tightened early-exit thresholds — see the brownout
/// runbook in `docs/OPERATIONS.md`).
pub const FLAG_DEGRADED: u8 = 0x01;

/// `lane` byte of the v7 EX requests: take the op's default lane
/// (single scores → interactive, batches → bulk).
pub const LANE_DEFAULT: u8 = 0;
/// `lane` byte: force the latency-sensitive interactive lane.
pub const LANE_INTERACTIVE: u8 = 1;
/// `lane` byte: force the throughput bulk lane.
pub const LANE_BULK: u8 = 2;

/// One per-example row of a `SCORE_BATCH_RESP` frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchResult {
    /// [`BATCH_STATUS_OK`], or the [`ErrorCode`] wire byte for this
    /// example's failure (`evaluated`/`score` are 0 in that case).
    pub status: u8,
    /// Features evaluated before the early exit.
    pub evaluated: u32,
    /// Signed margin estimate; the prediction is its sign.
    pub score: f64,
}

/// One decoded v2 frame (either direction).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Sparse score request: strictly increasing `idx` with parallel
    /// `val`, pinned to model generation `gen` (0 = any).
    ScoreSparse {
        /// Model generation pin (0 = any).
        gen: u32,
        /// Coordinate indices (u16 on the wire).
        idx: Vec<u16>,
        /// Values at those coordinates.
        val: Vec<f64>,
    },
    /// A v1 JSON request document riding inside a binary frame.
    JsonReq(String),
    /// v3 dense score request routed to model shard `model` (0 =
    /// default), pinned to generation `gen` (0 = any).
    ScoreDense {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// The full dense feature vector.
        val: Vec<f64>,
    },
    /// v3 sparse score request: like `ScoreSparse` but model-routed and
    /// with `u32` coordinate indices (models beyond 65536 dims fit).
    ScoreSparse2 {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// Coordinate indices (u32 on the wire), strictly increasing.
        idx: Vec<u32>,
        /// Values at those coordinates.
        val: Vec<f64>,
    },
    /// v3 sparse classify request: the attentive all-pairs vote on an
    /// ensemble shard. Same payload layout as `ScoreSparse2`.
    ClassifySparse {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// Coordinate indices (u32 on the wire), strictly increasing.
        idx: Vec<u32>,
        /// Values at those coordinates.
        val: Vec<f64>,
    },
    /// v3 sparse classify request asking for the per-voter breakdown
    /// (`CLASS_VERBOSE` response). Same payload layout as
    /// `ClassifySparse`.
    ClassifySparseVerbose {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// Coordinate indices (u32 on the wire), strictly increasing.
        idx: Vec<u32>,
        /// Values at those coordinates.
        val: Vec<f64>,
    },
    /// v4 sparse learn request: one labeled example for the routed
    /// shard's online trainer. Payload layout matches `ScoreSparse2`
    /// except a `label:i8` (±1) replaces the generation pin (learning
    /// always feeds the live trainer, never a pinned generation).
    LearnSparse {
        /// Interned model shard id.
        model: u16,
        /// Example label, ±1.
        label: i8,
        /// Coordinate indices (u32 on the wire), strictly increasing.
        idx: Vec<u32>,
        /// Values at those coordinates.
        val: Vec<f64>,
    },
    /// v6 batched sparse score request: up to the server's advertised
    /// `max_batch_examples` examples for one shard under one generation
    /// pin, admitted as a single queue slot and answered by one
    /// `SCORE_BATCH_RESP` frame.
    ScoreBatch {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any), shared by every example.
        gen: u32,
        /// Per-example `(idx, val)` sparse vectors, each with strictly
        /// increasing indices.
        examples: Vec<(Vec<u32>, Vec<f64>)>,
    },
    /// v7 sparse score request with admission extensions: the
    /// `ScoreSparse2` payload plus a relative deadline and a lane
    /// override. Answered by `ScoreEx`.
    ScoreSparseEx {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// Relative deadline in milliseconds (0 = none): still queued
        /// this long after admission, the request is answered
        /// `DEADLINE_EXCEEDED` at dequeue instead of being scored.
        deadline_ms: u32,
        /// Admission lane ([`LANE_DEFAULT`] / [`LANE_INTERACTIVE`] /
        /// [`LANE_BULK`]).
        lane: u8,
        /// Coordinate indices (u32 on the wire), strictly increasing.
        idx: Vec<u32>,
        /// Values at those coordinates.
        val: Vec<f64>,
    },
    /// v7 batched sparse score request with admission extensions: the
    /// `ScoreBatch` payload plus a relative deadline and a lane
    /// override, both shared by every example. Answered by
    /// `ScoreBatchRespEx`.
    ScoreBatchEx {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any), shared by every example.
        gen: u32,
        /// Relative deadline in milliseconds (0 = none); an expired
        /// batch is shed whole at dequeue.
        deadline_ms: u32,
        /// Admission lane ([`LANE_DEFAULT`] / [`LANE_INTERACTIVE`] /
        /// [`LANE_BULK`]).
        lane: u8,
        /// Per-example `(idx, val)` sparse vectors, each with strictly
        /// increasing indices.
        examples: Vec<(Vec<u32>, Vec<f64>)>,
    },
    /// Score response: the serving generation, coordinates evaluated,
    /// and the signed margin.
    Score {
        /// Generation that served the request.
        gen: u32,
        /// Features evaluated before the early exit.
        evaluated: u32,
        /// Signed margin estimate; the prediction is its sign.
        score: f64,
    },
    /// Structured error response.
    Error {
        /// What class of failure.
        code: ErrorCode,
        /// Whether retrying later can succeed.
        retryable: bool,
        /// Human-readable detail.
        msg: String,
    },
    /// A v1 JSON response document riding inside a binary frame.
    JsonResp(String),
    /// Classify response: the serving generation, the all-pairs vote
    /// outcome, and total features evaluated across voters.
    Class {
        /// Generation that served the request.
        gen: u32,
        /// Predicted class (vote winner; ties break toward the smaller
        /// label).
        label: i64,
        /// Votes the winner collected.
        votes: u32,
        /// Voters consulted (`C(C-1)/2`).
        voters: u32,
        /// Features evaluated, summed across voters.
        evaluated: u32,
    },
    /// Classify response with the per-voter cost breakdown: one row per
    /// 1-vs-1 voter in pair-enumeration order, attributing vote and
    /// features-touched to each.
    ClassVerbose {
        /// Generation that served the request.
        gen: u32,
        /// Predicted class (vote winner; ties break toward the smaller
        /// label).
        label: i64,
        /// Votes the winner collected.
        votes: u32,
        /// Voters consulted (`C(C-1)/2`).
        voters: u32,
        /// Features evaluated, summed across voters.
        evaluated: u32,
        /// Per-voter outcome rows, in pair-enumeration order.
        per_voter: Vec<VoterVote>,
    },
    /// Learn acknowledgement: the example was accepted into the shard's
    /// learn queue.
    LearnAck {
        /// The shard's *current serving* generation at ack time (learn
        /// is asynchronous: this generation does not yet reflect the
        /// acked example).
        gen: u32,
        /// Cumulative examples accepted by this shard's trainer.
        seen: u64,
    },
    /// v6 batched score response: one row per submitted example, in
    /// submission order, each with its own status byte so a rejected
    /// example never poisons its batchmates.
    ScoreBatchResp {
        /// Generation that served the batch.
        gen: u32,
        /// Per-example outcome rows, in submission order.
        results: Vec<BatchResult>,
    },
    /// v7 score response with flags (answers `ScoreSparseEx`).
    ScoreEx {
        /// Generation that served the request.
        gen: u32,
        /// Response flags ([`FLAG_DEGRADED`]).
        flags: u8,
        /// Features evaluated before the early exit.
        evaluated: u32,
        /// Signed margin estimate; the prediction is its sign.
        score: f64,
    },
    /// v7 batched score response with flags (answers `ScoreBatchEx`).
    ScoreBatchRespEx {
        /// Generation that served the batch.
        gen: u32,
        /// Response flags ([`FLAG_DEGRADED`]), shared by the batch.
        flags: u8,
        /// Per-example outcome rows, in submission order.
        results: Vec<BatchResult>,
    },
}

impl Frame {
    /// Encode into a length-prefixed wire buffer.
    ///
    /// # Panics
    ///
    /// A `ScoreSparse` frame with more than 65535 pairs (the wire
    /// format's `nnz:u16` bound; the v3 `ScoreSparse2`/`ClassifySparse`
    /// frames lift this to `u32`) or mismatched `idx`/`val` lengths is
    /// unrepresentable — encoding one panics instead of emitting a
    /// corrupt frame that would surface remotely as a fatal
    /// `BAD_FRAME` on an innocent-looking connection.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        self.encode_into(&mut out);
        out
    }

    /// Encode into a caller-supplied buffer (appended; the buffer is
    /// *not* cleared, so one buffer can batch many frames). This is the
    /// transport's allocation-free path: with a pooled or per-connection
    /// buffer at steady-state capacity, encoding touches no allocator.
    /// Panics exactly like [`Self::encode`] on unrepresentable frames.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        // Length-prefix placeholder, patched once the body is written.
        let prefix_at = out.len();
        out.extend_from_slice(&[0u8; 4]);
        match self {
            Frame::ScoreSparse { gen, idx, val } => {
                assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
                assert!(
                    idx.len() <= u16::MAX as usize,
                    "sparse frame nnz {} exceeds the u16 wire bound",
                    idx.len()
                );
                out.push(OP_SCORE_SPARSE);
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&(idx.len() as u16).to_le_bytes());
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    out.extend_from_slice(&i.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::JsonReq(doc) => {
                out.push(OP_JSON_REQ);
                out.extend_from_slice(doc.as_bytes());
            }
            Frame::ScoreDense { model, gen, val } => {
                assert!(
                    val.len() <= u32::MAX as usize,
                    "dense frame count {} exceeds the u32 wire bound",
                    val.len()
                );
                out.push(OP_SCORE_DENSE);
                out.extend_from_slice(&model.to_le_bytes());
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&(val.len() as u32).to_le_bytes());
                for &v in val {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::ScoreSparse2 { model, gen, idx, val }
            | Frame::ClassifySparse { model, gen, idx, val }
            | Frame::ClassifySparseVerbose { model, gen, idx, val } => {
                assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
                assert!(
                    idx.len() <= u32::MAX as usize,
                    "sparse frame nnz {} exceeds the u32 wire bound",
                    idx.len()
                );
                out.push(match self {
                    Frame::ClassifySparse { .. } => OP_CLASSIFY_SPARSE,
                    Frame::ClassifySparseVerbose { .. } => OP_CLASSIFY_SPARSE_VERBOSE,
                    _ => OP_SCORE_SPARSE2,
                });
                out.extend_from_slice(&model.to_le_bytes());
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    out.extend_from_slice(&i.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::LearnSparse { model, label, idx, val } => {
                assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
                assert!(
                    idx.len() <= u32::MAX as usize,
                    "sparse frame nnz {} exceeds the u32 wire bound",
                    idx.len()
                );
                assert!(
                    *label == 1 || *label == -1,
                    "learn label must be ±1, got {label}"
                );
                out.push(OP_LEARN_SPARSE);
                out.extend_from_slice(&model.to_le_bytes());
                out.push(*label as u8);
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    out.extend_from_slice(&i.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::ScoreBatch { model, gen, examples } => {
                assert!(
                    examples.len() <= u16::MAX as usize,
                    "batch count {} exceeds the u16 wire bound",
                    examples.len()
                );
                out.push(OP_SCORE_BATCH);
                out.extend_from_slice(&model.to_le_bytes());
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&(examples.len() as u16).to_le_bytes());
                for (idx, val) in examples {
                    assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
                    assert!(
                        idx.len() <= u32::MAX as usize,
                        "sparse frame nnz {} exceeds the u32 wire bound",
                        idx.len()
                    );
                    out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                    for (&i, &v) in idx.iter().zip(val.iter()) {
                        out.extend_from_slice(&i.to_le_bytes());
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            Frame::ScoreSparseEx { model, gen, deadline_ms, lane, idx, val } => {
                assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
                assert!(
                    idx.len() <= u32::MAX as usize,
                    "sparse frame nnz {} exceeds the u32 wire bound",
                    idx.len()
                );
                assert!(*lane <= LANE_BULK, "bad lane byte {lane}");
                out.push(OP_SCORE_SPARSE_EX);
                out.extend_from_slice(&model.to_le_bytes());
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&deadline_ms.to_le_bytes());
                out.push(*lane);
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    out.extend_from_slice(&i.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::ScoreBatchEx { model, gen, deadline_ms, lane, examples } => {
                assert!(
                    examples.len() <= u16::MAX as usize,
                    "batch count {} exceeds the u16 wire bound",
                    examples.len()
                );
                assert!(*lane <= LANE_BULK, "bad lane byte {lane}");
                out.push(OP_SCORE_BATCH_EX);
                out.extend_from_slice(&model.to_le_bytes());
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&deadline_ms.to_le_bytes());
                out.push(*lane);
                out.extend_from_slice(&(examples.len() as u16).to_le_bytes());
                for (idx, val) in examples {
                    assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
                    assert!(
                        idx.len() <= u32::MAX as usize,
                        "sparse frame nnz {} exceeds the u32 wire bound",
                        idx.len()
                    );
                    out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                    for (&i, &v) in idx.iter().zip(val.iter()) {
                        out.extend_from_slice(&i.to_le_bytes());
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            Frame::Score { gen, evaluated, score } => {
                out.push(OP_SCORE);
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&evaluated.to_le_bytes());
                out.extend_from_slice(&score.to_le_bytes());
            }
            Frame::Error { code, retryable, msg } => {
                out.push(OP_ERROR);
                out.push(*code as u8);
                out.push(u8::from(*retryable));
                let msg = &msg.as_bytes()[..msg.len().min(u16::MAX as usize)];
                out.extend_from_slice(&(msg.len() as u16).to_le_bytes());
                out.extend_from_slice(msg);
            }
            Frame::JsonResp(doc) => {
                out.push(OP_JSON_RESP);
                out.extend_from_slice(doc.as_bytes());
            }
            Frame::Class { gen, label, votes, voters, evaluated } => {
                out.push(OP_CLASS);
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&label.to_le_bytes());
                out.extend_from_slice(&votes.to_le_bytes());
                out.extend_from_slice(&voters.to_le_bytes());
                out.extend_from_slice(&evaluated.to_le_bytes());
            }
            Frame::ClassVerbose { gen, label, votes, voters, evaluated, per_voter } => {
                assert!(
                    per_voter.len() <= u32::MAX as usize,
                    "per-voter rows {} exceed the u32 wire bound",
                    per_voter.len()
                );
                out.push(OP_CLASS_VERBOSE);
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&label.to_le_bytes());
                out.extend_from_slice(&votes.to_le_bytes());
                out.extend_from_slice(&voters.to_le_bytes());
                out.extend_from_slice(&evaluated.to_le_bytes());
                out.extend_from_slice(&(per_voter.len() as u32).to_le_bytes());
                for row in per_voter {
                    out.extend_from_slice(&row.pos.to_le_bytes());
                    out.extend_from_slice(&row.neg.to_le_bytes());
                    out.extend_from_slice(&row.vote.to_le_bytes());
                    out.extend_from_slice(&row.features.to_le_bytes());
                }
            }
            Frame::LearnAck { gen, seen } => {
                out.push(OP_LEARN_ACK);
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&seen.to_le_bytes());
            }
            Frame::ScoreBatchResp { gen, results } => {
                assert!(
                    results.len() <= u16::MAX as usize,
                    "batch count {} exceeds the u16 wire bound",
                    results.len()
                );
                out.push(OP_SCORE_BATCH_RESP);
                out.extend_from_slice(&gen.to_le_bytes());
                out.extend_from_slice(&(results.len() as u16).to_le_bytes());
                for row in results {
                    out.push(row.status);
                    out.extend_from_slice(&row.evaluated.to_le_bytes());
                    out.extend_from_slice(&row.score.to_le_bytes());
                }
            }
            Frame::ScoreEx { gen, flags, evaluated, score } => {
                out.push(OP_SCORE_EX);
                out.extend_from_slice(&gen.to_le_bytes());
                out.push(*flags);
                out.extend_from_slice(&evaluated.to_le_bytes());
                out.extend_from_slice(&score.to_le_bytes());
            }
            Frame::ScoreBatchRespEx { gen, flags, results } => {
                assert!(
                    results.len() <= u16::MAX as usize,
                    "batch count {} exceeds the u16 wire bound",
                    results.len()
                );
                out.push(OP_SCORE_BATCH_RESP_EX);
                out.extend_from_slice(&gen.to_le_bytes());
                out.push(*flags);
                out.extend_from_slice(&(results.len() as u16).to_le_bytes());
                for row in results {
                    out.push(row.status);
                    out.extend_from_slice(&row.evaluated.to_le_bytes());
                    out.extend_from_slice(&row.score.to_le_bytes());
                }
            }
        }
        let body_len = (out.len() - prefix_at - 4) as u32;
        out[prefix_at..prefix_at + 4].copy_from_slice(&body_len.to_le_bytes());
    }

    /// Encode a sparse score request straight from `(idx, val)` slices
    /// into a reusable buffer — the legacy `0x01` frame with `u16`
    /// indices, so `idx` entries beyond `u16::MAX` (or more than 65535
    /// pairs) are an error rather than silent truncation. The loadgen
    /// hot loop uses this to avoid building a `Frame` (two `Vec`s) per
    /// request.
    pub fn put_score_sparse(
        out: &mut Vec<u8>,
        gen: u32,
        idx: &[u32],
        val: &[f64],
    ) -> Result<(), String> {
        assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
        if idx.len() > u16::MAX as usize || idx.iter().any(|&i| i > u16::MAX as u32) {
            return Err("idx exceeds the u16 wire bound".into());
        }
        let body_len = 1 + 4 + 2 + 10 * idx.len();
        out.extend_from_slice(&(body_len as u32).to_le_bytes());
        out.push(OP_SCORE_SPARSE);
        out.extend_from_slice(&gen.to_le_bytes());
        out.extend_from_slice(&(idx.len() as u16).to_le_bytes());
        for (&i, &v) in idx.iter().zip(val.iter()) {
            out.extend_from_slice(&(i as u16).to_le_bytes());
            out.extend_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    /// Encode a v3 sparse request (`SCORE_SPARSE2`, `CLASSIFY_SPARSE`,
    /// or `CLASSIFY_SPARSE_VERBOSE` — they share one layout) straight
    /// from `(idx, val)` slices into a reusable buffer.
    ///
    /// # Panics
    ///
    /// On an op byte outside the shared-layout trio, or mismatched
    /// slice lengths.
    pub fn put_sparse_v3(
        out: &mut Vec<u8>,
        op: u8,
        model: u16,
        gen: u32,
        idx: &[u32],
        val: &[f64],
    ) {
        assert!(
            matches!(op, OP_SCORE_SPARSE2 | OP_CLASSIFY_SPARSE | OP_CLASSIFY_SPARSE_VERBOSE),
            "op {op:#04x} does not use the v3 sparse layout"
        );
        assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
        let body_len = 1 + 2 + 4 + 4 + 12 * idx.len();
        out.extend_from_slice(&(body_len as u32).to_le_bytes());
        out.push(op);
        out.extend_from_slice(&model.to_le_bytes());
        out.extend_from_slice(&gen.to_le_bytes());
        out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        for (&i, &v) in idx.iter().zip(val.iter()) {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Encode a v7 `SCORE_SPARSE_EX` request straight from `(idx, val)`
    /// slices into a reusable buffer (the loadgen deadline hot loop).
    ///
    /// # Panics
    ///
    /// On a lane byte beyond [`LANE_BULK`] or mismatched slice lengths.
    pub fn put_sparse_ex(
        out: &mut Vec<u8>,
        model: u16,
        gen: u32,
        deadline_ms: u32,
        lane: u8,
        idx: &[u32],
        val: &[f64],
    ) {
        assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
        assert!(lane <= LANE_BULK, "bad lane byte {lane}");
        let body_len = 1 + 2 + 4 + 4 + 1 + 4 + 12 * idx.len();
        out.extend_from_slice(&(body_len as u32).to_le_bytes());
        out.push(OP_SCORE_SPARSE_EX);
        out.extend_from_slice(&model.to_le_bytes());
        out.extend_from_slice(&gen.to_le_bytes());
        out.extend_from_slice(&deadline_ms.to_le_bytes());
        out.push(lane);
        out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        for (&i, &v) in idx.iter().zip(val.iter()) {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Encode a v4 `LEARN_SPARSE` request straight from `(idx, val)`
    /// slices into a reusable buffer (the loadgen learn hot loop).
    ///
    /// # Panics
    ///
    /// On a label outside ±1 or mismatched slice lengths.
    pub fn put_learn_sparse(out: &mut Vec<u8>, model: u16, label: i8, idx: &[u32], val: &[f64]) {
        assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
        assert!(label == 1 || label == -1, "learn label must be ±1, got {label}");
        let body_len = 1 + 2 + 1 + 4 + 12 * idx.len();
        out.extend_from_slice(&(body_len as u32).to_le_bytes());
        out.push(OP_LEARN_SPARSE);
        out.extend_from_slice(&model.to_le_bytes());
        out.push(label as u8);
        out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        for (&i, &v) in idx.iter().zip(val.iter()) {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Start encoding a v6 `SCORE_BATCH` request straight into a
    /// reusable buffer. Examples are appended with
    /// [`BatchEncoder::push_example`] and the length prefix and count
    /// are patched by [`BatchEncoder::finish`] — the loadgen batch hot
    /// loop builds whole frames with zero allocation this way.
    pub fn begin_score_batch(out: &mut Vec<u8>, model: u16, gen: u32) -> BatchEncoder<'_> {
        BatchEncoder::begin(out, model, gen)
    }

    /// Start encoding a v7 `SCORE_BATCH_EX` request (the `SCORE_BATCH`
    /// layout with the admission fields) straight into a reusable
    /// buffer; examples and `finish` work exactly like
    /// [`Self::begin_score_batch`].
    ///
    /// # Panics
    ///
    /// On a lane byte beyond [`LANE_BULK`].
    pub fn begin_score_batch_ex(
        out: &mut Vec<u8>,
        model: u16,
        gen: u32,
        deadline_ms: u32,
        lane: u8,
    ) -> BatchEncoder<'_> {
        BatchEncoder::begin_ex(out, model, gen, deadline_ms, lane)
    }

    /// Start encoding a v6 `SCORE_BATCH_RESP` straight into a reusable
    /// buffer (the transport writer's pooled output frame). Rows are
    /// appended with [`BatchRespEncoder::push_result`] and the prefix
    /// and count are patched by [`BatchRespEncoder::finish`].
    pub fn begin_score_batch_resp(out: &mut Vec<u8>, gen: u32) -> BatchRespEncoder<'_> {
        BatchRespEncoder::begin(out, gen)
    }

    /// Start encoding a v7 `SCORE_BATCH_RESP_EX` (the
    /// `SCORE_BATCH_RESP` layout plus a `flags` byte) straight into a
    /// reusable buffer.
    pub fn begin_score_batch_resp_ex(
        out: &mut Vec<u8>,
        gen: u32,
        flags: u8,
    ) -> BatchRespEncoder<'_> {
        BatchRespEncoder::begin_ex(out, gen, flags)
    }

    /// Decode one frame body (the bytes after the length prefix).
    pub fn decode_body(body: &[u8]) -> Result<Frame, FrameError> {
        let (&op, payload) = body.split_first().ok_or(FrameError::Empty)?;
        match op {
            OP_SCORE_SPARSE => {
                if payload.len() < 6 {
                    return Err(FrameError::BadLayout("sparse header needs 6 bytes".into()));
                }
                let gen = u32::from_le_bytes(payload[0..4].try_into().unwrap());
                let nnz = u16::from_le_bytes(payload[4..6].try_into().unwrap()) as usize;
                let pairs = &payload[6..];
                if pairs.len() != nnz * 10 {
                    return Err(FrameError::BadLayout(format!(
                        "nnz {} declares {} pair bytes, frame carries {}",
                        nnz,
                        nnz * 10,
                        pairs.len()
                    )));
                }
                let mut idx = Vec::with_capacity(nnz);
                let mut val = Vec::with_capacity(nnz);
                for p in pairs.chunks_exact(10) {
                    idx.push(u16::from_le_bytes(p[0..2].try_into().unwrap()));
                    val.push(f64::from_le_bytes(p[2..10].try_into().unwrap()));
                }
                Ok(Frame::ScoreSparse { gen, idx, val })
            }
            OP_JSON_REQ => {
                let doc = std::str::from_utf8(payload).map_err(|_| FrameError::BadUtf8)?;
                Ok(Frame::JsonReq(doc.to_string()))
            }
            OP_SCORE_DENSE => {
                if payload.len() < 10 {
                    return Err(FrameError::BadLayout("dense header needs 10 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let count = u32::from_le_bytes(payload[6..10].try_into().unwrap()) as usize;
                let values = &payload[10..];
                // Divide instead of multiplying: `count * 8` can wrap on
                // 32-bit usize targets, letting a hostile count match a
                // tiny payload and abort on allocation.
                if values.len() % 8 != 0 || values.len() / 8 != count {
                    return Err(FrameError::BadLayout(format!(
                        "count {} does not match {} value bytes",
                        count,
                        values.len()
                    )));
                }
                let val = values
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Ok(Frame::ScoreDense { model, gen, val })
            }
            OP_SCORE_SPARSE2 | OP_CLASSIFY_SPARSE | OP_CLASSIFY_SPARSE_VERBOSE => {
                if payload.len() < 10 {
                    return Err(FrameError::BadLayout("sparse2 header needs 10 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let nnz = u32::from_le_bytes(payload[6..10].try_into().unwrap()) as usize;
                let pairs = &payload[10..];
                // Divide instead of multiplying: `nnz * 12` can wrap on
                // 32-bit usize targets (the legacy u16 frame never could).
                if pairs.len() % 12 != 0 || pairs.len() / 12 != nnz {
                    return Err(FrameError::BadLayout(format!(
                        "nnz {} does not match {} pair bytes",
                        nnz,
                        pairs.len()
                    )));
                }
                let mut idx = Vec::with_capacity(nnz);
                let mut val = Vec::with_capacity(nnz);
                for p in pairs.chunks_exact(12) {
                    idx.push(u32::from_le_bytes(p[0..4].try_into().unwrap()));
                    val.push(f64::from_le_bytes(p[4..12].try_into().unwrap()));
                }
                Ok(match op {
                    OP_CLASSIFY_SPARSE => Frame::ClassifySparse { model, gen, idx, val },
                    OP_CLASSIFY_SPARSE_VERBOSE => {
                        Frame::ClassifySparseVerbose { model, gen, idx, val }
                    }
                    _ => Frame::ScoreSparse2 { model, gen, idx, val },
                })
            }
            OP_LEARN_SPARSE => {
                if payload.len() < 7 {
                    return Err(FrameError::BadLayout("learn header needs 7 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let label = payload[2] as i8;
                if label != 1 && label != -1 {
                    return Err(FrameError::BadLayout(format!(
                        "learn label must be ±1, got byte {:#04x}",
                        payload[2]
                    )));
                }
                let nnz = u32::from_le_bytes(payload[3..7].try_into().unwrap()) as usize;
                let pairs = &payload[7..];
                // Divide instead of multiplying: `nnz * 12` can wrap on
                // 32-bit usize targets.
                if pairs.len() % 12 != 0 || pairs.len() / 12 != nnz {
                    return Err(FrameError::BadLayout(format!(
                        "nnz {} does not match {} pair bytes",
                        nnz,
                        pairs.len()
                    )));
                }
                let mut idx = Vec::with_capacity(nnz);
                let mut val = Vec::with_capacity(nnz);
                for p in pairs.chunks_exact(12) {
                    idx.push(u32::from_le_bytes(p[0..4].try_into().unwrap()));
                    val.push(f64::from_le_bytes(p[4..12].try_into().unwrap()));
                }
                Ok(Frame::LearnSparse { model, label, idx, val })
            }
            OP_SCORE_BATCH => {
                if payload.len() < 8 {
                    return Err(FrameError::BadLayout("batch header needs 8 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let count = u16::from_le_bytes(payload[6..8].try_into().unwrap()) as usize;
                let mut rest = &payload[8..];
                let mut examples = Vec::with_capacity(count);
                for n in 0..count {
                    if rest.len() < 4 {
                        return Err(FrameError::BadLayout(format!(
                            "batch example {n} header overruns frame"
                        )));
                    }
                    let nnz = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                    rest = &rest[4..];
                    // Divide instead of multiplying: `nnz * 12` can wrap
                    // on 32-bit usize targets.
                    if rest.len() / 12 < nnz {
                        return Err(FrameError::BadLayout(format!(
                            "batch example {n} nnz {nnz} overruns {} remaining bytes",
                            rest.len()
                        )));
                    }
                    let (pairs, tail) = rest.split_at(nnz * 12);
                    let mut idx = Vec::with_capacity(nnz);
                    let mut val = Vec::with_capacity(nnz);
                    for p in pairs.chunks_exact(12) {
                        idx.push(u32::from_le_bytes(p[0..4].try_into().unwrap()));
                        val.push(f64::from_le_bytes(p[4..12].try_into().unwrap()));
                    }
                    examples.push((idx, val));
                    rest = tail;
                }
                if !rest.is_empty() {
                    return Err(FrameError::BadLayout(format!(
                        "batch count {} leaves {} trailing bytes",
                        count,
                        rest.len()
                    )));
                }
                Ok(Frame::ScoreBatch { model, gen, examples })
            }
            OP_SCORE_SPARSE_EX => {
                if payload.len() < 15 {
                    return Err(FrameError::BadLayout("sparse-ex header needs 15 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let deadline_ms = u32::from_le_bytes(payload[6..10].try_into().unwrap());
                let lane = payload[10];
                if lane > LANE_BULK {
                    return Err(FrameError::BadLayout(format!("bad lane byte {lane}")));
                }
                let nnz = u32::from_le_bytes(payload[11..15].try_into().unwrap()) as usize;
                let pairs = &payload[15..];
                // Divide instead of multiplying: `nnz * 12` can wrap on
                // 32-bit usize targets.
                if pairs.len() % 12 != 0 || pairs.len() / 12 != nnz {
                    return Err(FrameError::BadLayout(format!(
                        "nnz {} does not match {} pair bytes",
                        nnz,
                        pairs.len()
                    )));
                }
                let mut idx = Vec::with_capacity(nnz);
                let mut val = Vec::with_capacity(nnz);
                for p in pairs.chunks_exact(12) {
                    idx.push(u32::from_le_bytes(p[0..4].try_into().unwrap()));
                    val.push(f64::from_le_bytes(p[4..12].try_into().unwrap()));
                }
                Ok(Frame::ScoreSparseEx { model, gen, deadline_ms, lane, idx, val })
            }
            OP_SCORE_BATCH_EX => {
                if payload.len() < 13 {
                    return Err(FrameError::BadLayout("batch-ex header needs 13 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let deadline_ms = u32::from_le_bytes(payload[6..10].try_into().unwrap());
                let lane = payload[10];
                if lane > LANE_BULK {
                    return Err(FrameError::BadLayout(format!("bad lane byte {lane}")));
                }
                let count = u16::from_le_bytes(payload[11..13].try_into().unwrap()) as usize;
                let mut rest = &payload[13..];
                let mut examples = Vec::with_capacity(count);
                for n in 0..count {
                    if rest.len() < 4 {
                        return Err(FrameError::BadLayout(format!(
                            "batch example {n} header overruns frame"
                        )));
                    }
                    let nnz = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                    rest = &rest[4..];
                    // Divide instead of multiplying: `nnz * 12` can wrap
                    // on 32-bit usize targets.
                    if rest.len() / 12 < nnz {
                        return Err(FrameError::BadLayout(format!(
                            "batch example {n} nnz {nnz} overruns {} remaining bytes",
                            rest.len()
                        )));
                    }
                    let (pairs, tail) = rest.split_at(nnz * 12);
                    let mut idx = Vec::with_capacity(nnz);
                    let mut val = Vec::with_capacity(nnz);
                    for p in pairs.chunks_exact(12) {
                        idx.push(u32::from_le_bytes(p[0..4].try_into().unwrap()));
                        val.push(f64::from_le_bytes(p[4..12].try_into().unwrap()));
                    }
                    examples.push((idx, val));
                    rest = tail;
                }
                if !rest.is_empty() {
                    return Err(FrameError::BadLayout(format!(
                        "batch count {} leaves {} trailing bytes",
                        count,
                        rest.len()
                    )));
                }
                Ok(Frame::ScoreBatchEx { model, gen, deadline_ms, lane, examples })
            }
            OP_SCORE => {
                if payload.len() != 16 {
                    return Err(FrameError::BadLayout(format!(
                        "score payload must be 16 bytes, got {}",
                        payload.len()
                    )));
                }
                Ok(Frame::Score {
                    gen: u32::from_le_bytes(payload[0..4].try_into().unwrap()),
                    evaluated: u32::from_le_bytes(payload[4..8].try_into().unwrap()),
                    score: f64::from_le_bytes(payload[8..16].try_into().unwrap()),
                })
            }
            OP_ERROR => {
                if payload.len() < 4 {
                    return Err(FrameError::BadLayout("error header needs 4 bytes".into()));
                }
                let code = ErrorCode::from_u8(payload[0])
                    .ok_or_else(|| FrameError::BadLayout(format!("bad error code {}", payload[0])))?;
                let retryable = payload[1] != 0;
                let msg_len = u16::from_le_bytes(payload[2..4].try_into().unwrap()) as usize;
                let msg = payload
                    .get(4..4 + msg_len)
                    .ok_or_else(|| FrameError::BadLayout("error msg overruns frame".into()))?;
                let msg =
                    std::str::from_utf8(msg).map_err(|_| FrameError::BadUtf8)?.to_string();
                Ok(Frame::Error { code, retryable, msg })
            }
            OP_JSON_RESP => {
                let doc = std::str::from_utf8(payload).map_err(|_| FrameError::BadUtf8)?;
                Ok(Frame::JsonResp(doc.to_string()))
            }
            OP_CLASS => {
                if payload.len() != 24 {
                    return Err(FrameError::BadLayout(format!(
                        "class payload must be 24 bytes, got {}",
                        payload.len()
                    )));
                }
                Ok(Frame::Class {
                    gen: u32::from_le_bytes(payload[0..4].try_into().unwrap()),
                    label: i64::from_le_bytes(payload[4..12].try_into().unwrap()),
                    votes: u32::from_le_bytes(payload[12..16].try_into().unwrap()),
                    voters: u32::from_le_bytes(payload[16..20].try_into().unwrap()),
                    evaluated: u32::from_le_bytes(payload[20..24].try_into().unwrap()),
                })
            }
            OP_CLASS_VERBOSE => {
                if payload.len() < 28 {
                    return Err(FrameError::BadLayout(
                        "class-verbose header needs 28 bytes".into(),
                    ));
                }
                let count = u32::from_le_bytes(payload[24..28].try_into().unwrap()) as usize;
                let rows = &payload[28..];
                // Divide, don't multiply: `count * 28` can wrap on
                // 32-bit usize targets.
                if rows.len() % 28 != 0 || rows.len() / 28 != count {
                    return Err(FrameError::BadLayout(format!(
                        "per-voter count {} does not match {} row bytes",
                        count,
                        rows.len()
                    )));
                }
                let per_voter = rows
                    .chunks_exact(28)
                    .map(|r| VoterVote {
                        pos: i64::from_le_bytes(r[0..8].try_into().unwrap()),
                        neg: i64::from_le_bytes(r[8..16].try_into().unwrap()),
                        vote: i64::from_le_bytes(r[16..24].try_into().unwrap()),
                        features: u32::from_le_bytes(r[24..28].try_into().unwrap()),
                    })
                    .collect();
                Ok(Frame::ClassVerbose {
                    gen: u32::from_le_bytes(payload[0..4].try_into().unwrap()),
                    label: i64::from_le_bytes(payload[4..12].try_into().unwrap()),
                    votes: u32::from_le_bytes(payload[12..16].try_into().unwrap()),
                    voters: u32::from_le_bytes(payload[16..20].try_into().unwrap()),
                    evaluated: u32::from_le_bytes(payload[20..24].try_into().unwrap()),
                    per_voter,
                })
            }
            OP_LEARN_ACK => {
                if payload.len() != 12 {
                    return Err(FrameError::BadLayout(format!(
                        "learn-ack payload must be 12 bytes, got {}",
                        payload.len()
                    )));
                }
                Ok(Frame::LearnAck {
                    gen: u32::from_le_bytes(payload[0..4].try_into().unwrap()),
                    seen: u64::from_le_bytes(payload[4..12].try_into().unwrap()),
                })
            }
            OP_SCORE_BATCH_RESP => {
                if payload.len() < 6 {
                    return Err(FrameError::BadLayout("batch-resp header needs 6 bytes".into()));
                }
                let gen = u32::from_le_bytes(payload[0..4].try_into().unwrap());
                let count = u16::from_le_bytes(payload[4..6].try_into().unwrap()) as usize;
                let rows = &payload[6..];
                // Divide, don't multiply: `count * 13` can wrap on
                // 32-bit usize targets.
                if rows.len() % 13 != 0 || rows.len() / 13 != count {
                    return Err(FrameError::BadLayout(format!(
                        "batch-resp count {} does not match {} row bytes",
                        count,
                        rows.len()
                    )));
                }
                let results = rows
                    .chunks_exact(13)
                    .map(|r| BatchResult {
                        status: r[0],
                        evaluated: u32::from_le_bytes(r[1..5].try_into().unwrap()),
                        score: f64::from_le_bytes(r[5..13].try_into().unwrap()),
                    })
                    .collect();
                Ok(Frame::ScoreBatchResp { gen, results })
            }
            OP_SCORE_EX => {
                if payload.len() != 17 {
                    return Err(FrameError::BadLayout(format!(
                        "score-ex payload must be 17 bytes, got {}",
                        payload.len()
                    )));
                }
                Ok(Frame::ScoreEx {
                    gen: u32::from_le_bytes(payload[0..4].try_into().unwrap()),
                    flags: payload[4],
                    evaluated: u32::from_le_bytes(payload[5..9].try_into().unwrap()),
                    score: f64::from_le_bytes(payload[9..17].try_into().unwrap()),
                })
            }
            OP_SCORE_BATCH_RESP_EX => {
                if payload.len() < 7 {
                    return Err(FrameError::BadLayout(
                        "batch-resp-ex header needs 7 bytes".into(),
                    ));
                }
                let gen = u32::from_le_bytes(payload[0..4].try_into().unwrap());
                let flags = payload[4];
                let count = u16::from_le_bytes(payload[5..7].try_into().unwrap()) as usize;
                let rows = &payload[7..];
                // Divide, don't multiply: `count * 13` can wrap on
                // 32-bit usize targets.
                if rows.len() % 13 != 0 || rows.len() / 13 != count {
                    return Err(FrameError::BadLayout(format!(
                        "batch-resp-ex count {} does not match {} row bytes",
                        count,
                        rows.len()
                    )));
                }
                let results = rows
                    .chunks_exact(13)
                    .map(|r| BatchResult {
                        status: r[0],
                        evaluated: u32::from_le_bytes(r[1..5].try_into().unwrap()),
                        score: f64::from_le_bytes(r[5..13].try_into().unwrap()),
                    })
                    .collect();
                Ok(Frame::ScoreBatchRespEx { gen, flags, results })
            }
            other => Err(FrameError::BadOp(other)),
        }
    }

    /// Read one frame *body* (the bytes after the length prefix) into a
    /// caller-supplied buffer, which is cleared and refilled — a loop
    /// reading many frames through one buffer reaches a steady state
    /// with zero allocation. `max_len` caps the body length (a hostile
    /// or corrupt prefix must not allocate gigabytes).
    /// [`FrameError::Eof`] means the peer closed cleanly between frames.
    pub fn read_body(
        reader: &mut impl Read,
        body: &mut Vec<u8>,
        max_len: usize,
    ) -> Result<(), FrameError> {
        let mut prefix = [0u8; 4];
        // A clean close before any prefix byte is EOF, not truncation.
        match reader.read(&mut prefix) {
            Ok(0) => return Err(FrameError::Eof),
            Ok(n) => {
                if n < 4 {
                    reader
                        .read_exact(&mut prefix[n..])
                        .map_err(|e| FrameError::Truncated(e.to_string()))?;
                }
            }
            Err(e) => return Err(FrameError::Truncated(e.to_string())),
        }
        let len = u32::from_le_bytes(prefix) as usize;
        if len > max_len {
            return Err(FrameError::TooLarge { len, max: max_len });
        }
        if len == 0 {
            return Err(FrameError::Empty);
        }
        body.clear();
        body.resize(len, 0);
        reader.read_exact(body).map_err(|e| FrameError::Truncated(e.to_string()))?;
        Ok(())
    }

    /// Read and decode one frame from a stream (see [`Self::read_body`]
    /// for the length-cap and EOF semantics).
    pub fn read_from(reader: &mut impl Read, max_len: usize) -> Result<Frame, FrameError> {
        let mut body = Vec::new();
        Frame::read_body(reader, &mut body, max_len)?;
        Frame::decode_body(&body)
    }

    /// Decode one length-prefixed frame from a buffer (tests/tools).
    /// Returns the frame and the bytes consumed.
    pub fn decode(buf: &[u8], max_len: usize) -> Result<(Frame, usize), FrameError> {
        if buf.len() < 4 {
            return Err(FrameError::Truncated(format!("{} prefix bytes", buf.len())));
        }
        let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
        if len > max_len {
            return Err(FrameError::TooLarge { len, max: max_len });
        }
        let body = buf
            .get(4..4 + len)
            .ok_or_else(|| FrameError::Truncated(format!("body wants {len} bytes")))?;
        Ok((Frame::decode_body(body)?, 4 + len))
    }
}

/// Incremental, allocation-free encoder for a v6 `SCORE_BATCH` frame
/// (see [`Frame::begin_score_batch`]). The length prefix and example
/// count are written as placeholders and patched by [`Self::finish`];
/// dropping the encoder without calling `finish` leaves a corrupt
/// placeholder frame in the buffer, so `finish` is not optional.
#[derive(Debug)]
pub struct BatchEncoder<'b> {
    out: &'b mut Vec<u8>,
    prefix_at: usize,
    count_at: usize,
    count: u16,
}

impl<'b> BatchEncoder<'b> {
    fn begin(out: &'b mut Vec<u8>, model: u16, gen: u32) -> Self {
        let prefix_at = out.len();
        out.extend_from_slice(&[0u8; 4]);
        out.push(OP_SCORE_BATCH);
        out.extend_from_slice(&model.to_le_bytes());
        out.extend_from_slice(&gen.to_le_bytes());
        let count_at = out.len();
        out.extend_from_slice(&0u16.to_le_bytes()); // count placeholder
        Self { out, prefix_at, count_at, count: 0 }
    }

    fn begin_ex(out: &'b mut Vec<u8>, model: u16, gen: u32, deadline_ms: u32, lane: u8) -> Self {
        assert!(lane <= LANE_BULK, "bad lane byte {lane}");
        let prefix_at = out.len();
        out.extend_from_slice(&[0u8; 4]);
        out.push(OP_SCORE_BATCH_EX);
        out.extend_from_slice(&model.to_le_bytes());
        out.extend_from_slice(&gen.to_le_bytes());
        out.extend_from_slice(&deadline_ms.to_le_bytes());
        out.push(lane);
        let count_at = out.len();
        out.extend_from_slice(&0u16.to_le_bytes()); // count placeholder
        Self { out, prefix_at, count_at, count: 0 }
    }

    /// Append one sparse example.
    ///
    /// # Panics
    ///
    /// On mismatched `idx`/`val` lengths, an `nnz` beyond the `u32`
    /// wire bound, or a 65536th example (the `count:u16` wire bound).
    pub fn push_example(&mut self, idx: &[u32], val: &[f64]) {
        assert_eq!(idx.len(), val.len(), "sparse idx/val length mismatch");
        assert!(
            idx.len() <= u32::MAX as usize,
            "sparse frame nnz {} exceeds the u32 wire bound",
            idx.len()
        );
        assert!(self.count < u16::MAX, "batch count exceeds the u16 wire bound");
        self.count += 1;
        self.out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        for (&i, &v) in idx.iter().zip(val.iter()) {
            self.out.extend_from_slice(&i.to_le_bytes());
            self.out.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Patch the length prefix and example count, completing the frame.
    /// Returns the number of examples pushed.
    pub fn finish(self) -> usize {
        let body_len = (self.out.len() - self.prefix_at - 4) as u32;
        self.out[self.prefix_at..self.prefix_at + 4].copy_from_slice(&body_len.to_le_bytes());
        self.out[self.count_at..self.count_at + 2].copy_from_slice(&self.count.to_le_bytes());
        self.count as usize
    }
}

/// Incremental, allocation-free encoder for a v6 `SCORE_BATCH_RESP`
/// frame (see [`Frame::begin_score_batch_resp`]); the transport writer
/// renders a whole batch's outcomes into one pooled buffer with this.
/// Like [`BatchEncoder`], [`Self::finish`] is not optional.
#[derive(Debug)]
pub struct BatchRespEncoder<'b> {
    out: &'b mut Vec<u8>,
    prefix_at: usize,
    count_at: usize,
    count: u16,
}

impl<'b> BatchRespEncoder<'b> {
    fn begin(out: &'b mut Vec<u8>, gen: u32) -> Self {
        let prefix_at = out.len();
        out.extend_from_slice(&[0u8; 4]);
        out.push(OP_SCORE_BATCH_RESP);
        out.extend_from_slice(&gen.to_le_bytes());
        let count_at = out.len();
        out.extend_from_slice(&0u16.to_le_bytes()); // count placeholder
        Self { out, prefix_at, count_at, count: 0 }
    }

    fn begin_ex(out: &'b mut Vec<u8>, gen: u32, flags: u8) -> Self {
        let prefix_at = out.len();
        out.extend_from_slice(&[0u8; 4]);
        out.push(OP_SCORE_BATCH_RESP_EX);
        out.extend_from_slice(&gen.to_le_bytes());
        out.push(flags);
        let count_at = out.len();
        out.extend_from_slice(&0u16.to_le_bytes()); // count placeholder
        Self { out, prefix_at, count_at, count: 0 }
    }

    /// Append one per-example outcome row.
    ///
    /// # Panics
    ///
    /// On a 65536th row (the `count:u16` wire bound).
    pub fn push_result(&mut self, status: u8, evaluated: u32, score: f64) {
        assert!(self.count < u16::MAX, "batch count exceeds the u16 wire bound");
        self.count += 1;
        self.out.push(status);
        self.out.extend_from_slice(&evaluated.to_le_bytes());
        self.out.extend_from_slice(&score.to_le_bytes());
    }

    /// Patch the length prefix and row count, completing the frame.
    /// Returns the number of rows pushed.
    pub fn finish(self) -> usize {
        let body_len = (self.out.len() - self.prefix_at - 4) as u32;
        self.out[self.prefix_at..self.prefix_at + 4].copy_from_slice(&body_len.to_le_bytes());
        self.out[self.count_at..self.count_at + 2].copy_from_slice(&self.count.to_le_bytes());
        self.count as usize
    }
}

/// One request frame parsed without copying its payload: sparse pairs
/// and dense values stay as byte slices into the connection's read
/// buffer. The server's hot path decodes with this, screens the slices
/// in place ([`validate_pairs_u32`] etc.), and only materializes owned
/// [`Features`] at admission time ([`pairs_to_features_u32`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FrameRef<'a> {
    /// Legacy `0x01` sparse score: 10-byte `(idx:u16, val:f64)` pairs,
    /// always the default shard.
    ScoreSparse {
        /// Model generation pin (0 = any).
        gen: u32,
        /// Raw pair bytes, length a multiple of 10.
        pairs: &'a [u8],
    },
    /// A v1 JSON request document riding inside a binary frame.
    JsonReq(&'a str),
    /// v3 dense score: raw f64-LE values.
    ScoreDense {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// Raw value bytes, length a multiple of 8.
        vals: &'a [u8],
    },
    /// v3 sparse score: 12-byte `(idx:u32, val:f64)` pairs.
    ScoreSparse2 {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// Raw pair bytes, length a multiple of 12.
        pairs: &'a [u8],
    },
    /// v3 sparse classify (same layout as `ScoreSparse2`); `verbose`
    /// asks for the per-voter `CLASS_VERBOSE` breakdown.
    ClassifySparse {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// Raw pair bytes, length a multiple of 12.
        pairs: &'a [u8],
        /// Answer with the per-voter breakdown (`0x85`).
        verbose: bool,
    },
    /// v4 sparse learn: 12-byte `(idx:u32, val:f64)` pairs plus the ±1
    /// example label.
    LearnSparse {
        /// Interned model shard id.
        model: u16,
        /// Example label, ±1.
        label: i8,
        /// Raw pair bytes, length a multiple of 12.
        pairs: &'a [u8],
    },
    /// v6 batched sparse score: `count` examples, each an `nnz:u32`
    /// header followed by 12-byte `(idx:u32, val:f64)` pairs. The
    /// structural walk is done at decode time, so [`batch_pairs`]
    /// iteration over `examples` cannot overrun.
    ScoreBatch {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any), shared by every example.
        gen: u32,
        /// Number of examples carried.
        count: usize,
        /// Raw example bytes (the payload after the count field).
        examples: &'a [u8],
    },
    /// v7 sparse score with admission fields (`ScoreSparse2` layout
    /// plus a deadline and a lane override).
    ScoreSparseEx {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any).
        gen: u32,
        /// Relative deadline in milliseconds; 0 = none.
        deadline_ms: u32,
        /// Admission lane byte (`LANE_DEFAULT`/`LANE_INTERACTIVE`/
        /// `LANE_BULK`).
        lane: u8,
        /// Raw pair bytes, length a multiple of 12.
        pairs: &'a [u8],
    },
    /// v7 batched sparse score with admission fields (`ScoreBatch`
    /// layout plus a deadline and a lane override).
    ScoreBatchEx {
        /// Interned model shard id.
        model: u16,
        /// Model generation pin (0 = any), shared by every example.
        gen: u32,
        /// Relative deadline in milliseconds; 0 = none.
        deadline_ms: u32,
        /// Admission lane byte (`LANE_DEFAULT`/`LANE_INTERACTIVE`/
        /// `LANE_BULK`).
        lane: u8,
        /// Number of examples carried.
        count: usize,
        /// Raw example bytes (the payload after the count field).
        examples: &'a [u8],
    },
    /// A response op (`0x80..`) sent by the peer — protocol abuse on
    /// the server side; carried so the caller can report it without
    /// paying for a full decode.
    Response(u8),
}

impl<'a> FrameRef<'a> {
    /// Parse one frame body without copying the payload. Layout errors
    /// mirror [`Frame::decode_body`] exactly, so both decoders reject
    /// the same wire bytes.
    pub fn decode_borrowed(body: &'a [u8]) -> Result<FrameRef<'a>, FrameError> {
        let (&op, payload) = body.split_first().ok_or(FrameError::Empty)?;
        match op {
            OP_SCORE_SPARSE => {
                if payload.len() < 6 {
                    return Err(FrameError::BadLayout("sparse header needs 6 bytes".into()));
                }
                let gen = u32::from_le_bytes(payload[0..4].try_into().unwrap());
                let nnz = u16::from_le_bytes(payload[4..6].try_into().unwrap()) as usize;
                let pairs = &payload[6..];
                if pairs.len() != nnz * 10 {
                    return Err(FrameError::BadLayout(format!(
                        "nnz {} declares {} pair bytes, frame carries {}",
                        nnz,
                        nnz * 10,
                        pairs.len()
                    )));
                }
                Ok(FrameRef::ScoreSparse { gen, pairs })
            }
            OP_JSON_REQ => {
                let doc = std::str::from_utf8(payload).map_err(|_| FrameError::BadUtf8)?;
                Ok(FrameRef::JsonReq(doc))
            }
            OP_SCORE_DENSE => {
                if payload.len() < 10 {
                    return Err(FrameError::BadLayout("dense header needs 10 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let count = u32::from_le_bytes(payload[6..10].try_into().unwrap()) as usize;
                let vals = &payload[10..];
                if vals.len() % 8 != 0 || vals.len() / 8 != count {
                    return Err(FrameError::BadLayout(format!(
                        "count {} does not match {} value bytes",
                        count,
                        vals.len()
                    )));
                }
                Ok(FrameRef::ScoreDense { model, gen, vals })
            }
            OP_SCORE_SPARSE2 | OP_CLASSIFY_SPARSE | OP_CLASSIFY_SPARSE_VERBOSE => {
                if payload.len() < 10 {
                    return Err(FrameError::BadLayout("sparse2 header needs 10 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let nnz = u32::from_le_bytes(payload[6..10].try_into().unwrap()) as usize;
                let pairs = &payload[10..];
                if pairs.len() % 12 != 0 || pairs.len() / 12 != nnz {
                    return Err(FrameError::BadLayout(format!(
                        "nnz {} does not match {} pair bytes",
                        nnz,
                        pairs.len()
                    )));
                }
                Ok(match op {
                    OP_SCORE_SPARSE2 => FrameRef::ScoreSparse2 { model, gen, pairs },
                    verbose_op => FrameRef::ClassifySparse {
                        model,
                        gen,
                        pairs,
                        verbose: verbose_op == OP_CLASSIFY_SPARSE_VERBOSE,
                    },
                })
            }
            OP_LEARN_SPARSE => {
                if payload.len() < 7 {
                    return Err(FrameError::BadLayout("learn header needs 7 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let label = payload[2] as i8;
                if label != 1 && label != -1 {
                    return Err(FrameError::BadLayout(format!(
                        "learn label must be ±1, got byte {:#04x}",
                        payload[2]
                    )));
                }
                let nnz = u32::from_le_bytes(payload[3..7].try_into().unwrap()) as usize;
                let pairs = &payload[7..];
                if pairs.len() % 12 != 0 || pairs.len() / 12 != nnz {
                    return Err(FrameError::BadLayout(format!(
                        "nnz {} does not match {} pair bytes",
                        nnz,
                        pairs.len()
                    )));
                }
                Ok(FrameRef::LearnSparse { model, label, pairs })
            }
            OP_SCORE_BATCH => {
                if payload.len() < 8 {
                    return Err(FrameError::BadLayout("batch header needs 8 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let count = u16::from_le_bytes(payload[6..8].try_into().unwrap()) as usize;
                let examples = &payload[8..];
                // Structural walk only (O(count) header reads, no
                // per-pair work): after this, iteration cannot overrun.
                let mut rest = examples;
                for n in 0..count {
                    if rest.len() < 4 {
                        return Err(FrameError::BadLayout(format!(
                            "batch example {n} header overruns frame"
                        )));
                    }
                    let nnz = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                    rest = &rest[4..];
                    if rest.len() / 12 < nnz {
                        return Err(FrameError::BadLayout(format!(
                            "batch example {n} nnz {nnz} overruns {} remaining bytes",
                            rest.len()
                        )));
                    }
                    rest = &rest[nnz * 12..];
                }
                if !rest.is_empty() {
                    return Err(FrameError::BadLayout(format!(
                        "batch count {} leaves {} trailing bytes",
                        count,
                        rest.len()
                    )));
                }
                Ok(FrameRef::ScoreBatch { model, gen, count, examples })
            }
            OP_SCORE_SPARSE_EX => {
                if payload.len() < 15 {
                    return Err(FrameError::BadLayout("sparse-ex header needs 15 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let deadline_ms = u32::from_le_bytes(payload[6..10].try_into().unwrap());
                let lane = payload[10];
                if lane > LANE_BULK {
                    return Err(FrameError::BadLayout(format!("bad lane byte {lane}")));
                }
                let nnz = u32::from_le_bytes(payload[11..15].try_into().unwrap()) as usize;
                let pairs = &payload[15..];
                if pairs.len() % 12 != 0 || pairs.len() / 12 != nnz {
                    return Err(FrameError::BadLayout(format!(
                        "nnz {} does not match {} pair bytes",
                        nnz,
                        pairs.len()
                    )));
                }
                Ok(FrameRef::ScoreSparseEx { model, gen, deadline_ms, lane, pairs })
            }
            OP_SCORE_BATCH_EX => {
                if payload.len() < 13 {
                    return Err(FrameError::BadLayout("batch-ex header needs 13 bytes".into()));
                }
                let model = u16::from_le_bytes(payload[0..2].try_into().unwrap());
                let gen = u32::from_le_bytes(payload[2..6].try_into().unwrap());
                let deadline_ms = u32::from_le_bytes(payload[6..10].try_into().unwrap());
                let lane = payload[10];
                if lane > LANE_BULK {
                    return Err(FrameError::BadLayout(format!("bad lane byte {lane}")));
                }
                let count = u16::from_le_bytes(payload[11..13].try_into().unwrap()) as usize;
                let examples = &payload[13..];
                // Structural walk only (O(count) header reads, no
                // per-pair work): after this, iteration cannot overrun.
                let mut rest = examples;
                for n in 0..count {
                    if rest.len() < 4 {
                        return Err(FrameError::BadLayout(format!(
                            "batch example {n} header overruns frame"
                        )));
                    }
                    let nnz = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                    rest = &rest[4..];
                    if rest.len() / 12 < nnz {
                        return Err(FrameError::BadLayout(format!(
                            "batch example {n} nnz {nnz} overruns {} remaining bytes",
                            rest.len()
                        )));
                    }
                    rest = &rest[nnz * 12..];
                }
                if !rest.is_empty() {
                    return Err(FrameError::BadLayout(format!(
                        "batch count {} leaves {} trailing bytes",
                        count,
                        rest.len()
                    )));
                }
                Ok(FrameRef::ScoreBatchEx { model, gen, deadline_ms, lane, count, examples })
            }
            OP_SCORE | OP_ERROR | OP_JSON_RESP | OP_CLASS | OP_CLASS_VERBOSE | OP_LEARN_ACK
            | OP_SCORE_BATCH_RESP | OP_SCORE_EX | OP_SCORE_BATCH_RESP_EX => {
                Ok(FrameRef::Response(op))
            }
            other => Err(FrameError::BadOp(other)),
        }
    }

    /// Stored coordinates in this frame's payload (dense: full length;
    /// batch: summed across examples).
    pub fn nnz(&self) -> usize {
        match self {
            FrameRef::ScoreSparse { pairs, .. } => pairs.len() / 10,
            FrameRef::ScoreSparse2 { pairs, .. }
            | FrameRef::ClassifySparse { pairs, .. }
            | FrameRef::LearnSparse { pairs, .. }
            | FrameRef::ScoreSparseEx { pairs, .. } => pairs.len() / 12,
            FrameRef::ScoreDense { vals, .. } => vals.len() / 8,
            // Validated structure: total = count × 4 header bytes +
            // 12 bytes per stored pair.
            FrameRef::ScoreBatch { count, examples, .. }
            | FrameRef::ScoreBatchEx { count, examples, .. } => {
                (examples.len() - 4 * count) / 12
            }
            FrameRef::JsonReq(_) | FrameRef::Response(_) => 0,
        }
    }
}

/// Iterator over the per-example 12-byte pair slices of a
/// [`FrameRef::ScoreBatch`] payload, in submission order. The decode
/// already proved the structure, so each yielded slice is exactly that
/// example's `nnz × 12` pair bytes, ready for [`validate_pairs_u32`]
/// and [`pairs_to_features_u32`] — nothing is copied.
#[derive(Debug, Clone)]
pub struct BatchPairs<'a> {
    rest: &'a [u8],
}

impl<'a> Iterator for BatchPairs<'a> {
    type Item = &'a [u8];

    fn next(&mut self) -> Option<&'a [u8]> {
        if self.rest.len() < 4 {
            return None;
        }
        let nnz = u32::from_le_bytes(self.rest[0..4].try_into().unwrap()) as usize;
        let rest = &self.rest[4..];
        if rest.len() / 12 < nnz {
            // Unreachable on a validated payload; stop rather than panic.
            self.rest = &[];
            return None;
        }
        let (pairs, tail) = rest.split_at(nnz * 12);
        self.rest = tail;
        Some(pairs)
    }
}

/// Iterate the examples of a validated `SCORE_BATCH` payload (the
/// `examples` bytes of [`FrameRef::ScoreBatch`]).
pub fn batch_pairs(examples: &[u8]) -> BatchPairs<'_> {
    BatchPairs { rest: examples }
}

/// In-place structural screen for legacy 10-byte `(idx:u16, val:f64)`
/// pairs: strictly increasing indices, finite values. No allocation.
/// Error strings match [`Features::validate`], so both wire paths
/// reject with identical messages.
pub fn validate_pairs_u16(pairs: &[u8]) -> Result<(), &'static str> {
    let mut prev: i64 = -1;
    for p in pairs.chunks_exact(10) {
        let i = u16::from_le_bytes(p[0..2].try_into().unwrap()) as i64;
        if i <= prev {
            return Err("sparse idx must be strictly increasing");
        }
        prev = i;
        if !f64::from_le_bytes(p[2..10].try_into().unwrap()).is_finite() {
            return Err("non-finite feature value");
        }
    }
    Ok(())
}

/// In-place structural screen for v3 12-byte `(idx:u32, val:f64)`
/// pairs (see [`validate_pairs_u16`]).
pub fn validate_pairs_u32(pairs: &[u8]) -> Result<(), &'static str> {
    let mut prev: i64 = -1;
    for p in pairs.chunks_exact(12) {
        let i = u32::from_le_bytes(p[0..4].try_into().unwrap()) as i64;
        if i <= prev {
            return Err("sparse idx must be strictly increasing");
        }
        prev = i;
        if !f64::from_le_bytes(p[4..12].try_into().unwrap()).is_finite() {
            return Err("non-finite feature value");
        }
    }
    Ok(())
}

/// In-place finiteness screen for raw f64-LE dense values.
pub fn validate_dense_vals(vals: &[u8]) -> Result<(), &'static str> {
    for v in vals.chunks_exact(8) {
        if !f64::from_le_bytes(v.try_into().unwrap()).is_finite() {
            return Err("non-finite feature value");
        }
    }
    Ok(())
}

/// Materialize owned [`Features`] from validated legacy u16 pairs —
/// the admission-time allocation, deferred past every screen.
pub fn pairs_to_features_u16(pairs: &[u8]) -> Features {
    let nnz = pairs.len() / 10;
    let mut idx = Vec::with_capacity(nnz);
    let mut val = Vec::with_capacity(nnz);
    for p in pairs.chunks_exact(10) {
        idx.push(u16::from_le_bytes(p[0..2].try_into().unwrap()) as u32);
        val.push(f64::from_le_bytes(p[2..10].try_into().unwrap()));
    }
    Features::Sparse { idx, val }
}

/// Materialize owned [`Features`] from validated v3 u32 pairs.
pub fn pairs_to_features_u32(pairs: &[u8]) -> Features {
    let nnz = pairs.len() / 12;
    let mut idx = Vec::with_capacity(nnz);
    let mut val = Vec::with_capacity(nnz);
    for p in pairs.chunks_exact(12) {
        idx.push(u32::from_le_bytes(p[0..4].try_into().unwrap()));
        val.push(f64::from_le_bytes(p[4..12].try_into().unwrap()));
    }
    Features::Sparse { idx, val }
}

/// Materialize owned dense [`Features`] from raw f64-LE bytes.
pub fn dense_to_features(vals: &[u8]) -> Features {
    Features::Dense(
        vals.chunks_exact(8).map(|v| f64::from_le_bytes(v.try_into().unwrap())).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const MAX: usize = 1 << 20;

    fn round_trip(frame: Frame) {
        let wire = frame.encode();
        let (back, used) = Frame::decode(&wire, MAX).expect("decode");
        assert_eq!(used, wire.len(), "no trailing bytes");
        assert_eq!(back, frame);
    }

    #[test]
    fn all_ops_round_trip() {
        round_trip(Frame::ScoreSparse {
            gen: 7,
            idx: vec![0, 13, 783],
            val: vec![0.25, -1.5, 1.0],
        });
        round_trip(Frame::ScoreSparse { gen: 0, idx: vec![], val: vec![] });
        round_trip(Frame::JsonReq(r#"{"op":"stats"}"#.into()));
        round_trip(Frame::ScoreDense { model: 3, gen: 2, val: vec![0.5, -1.0, 0.0] });
        round_trip(Frame::ScoreDense { model: 0, gen: 0, val: vec![] });
        round_trip(Frame::ScoreSparse2 {
            model: 1,
            gen: 9,
            // Indices beyond the legacy u16 bound must survive.
            idx: vec![0, 70_000, 4_000_000_000],
            val: vec![0.25, -1.5, 1.0],
        });
        round_trip(Frame::ClassifySparse {
            model: 2,
            gen: 4,
            idx: vec![5, 100_000],
            val: vec![1.0, 2.0],
        });
        round_trip(Frame::Class { gen: 7, label: -3, votes: 9, voters: 45, evaluated: 1234 });
        round_trip(Frame::Score { gen: 3, evaluated: 41, score: -0.75 });
        round_trip(Frame::Error {
            code: ErrorCode::Overloaded,
            retryable: true,
            msg: "overloaded".into(),
        });
        round_trip(Frame::JsonResp(r#"{"ok":true,"op":"pong"}"#.into()));
        round_trip(Frame::ScoreSparseEx {
            model: 1,
            gen: 9,
            deadline_ms: 250,
            lane: LANE_INTERACTIVE,
            idx: vec![0, 70_000, 4_000_000_000],
            val: vec![0.25, -1.5, 1.0],
        });
        round_trip(Frame::ScoreSparseEx {
            model: 0,
            gen: 0,
            deadline_ms: 0,
            lane: LANE_DEFAULT,
            idx: vec![],
            val: vec![],
        });
        round_trip(Frame::ScoreBatchEx {
            model: 2,
            gen: 5,
            deadline_ms: 1_000,
            lane: LANE_BULK,
            examples: vec![(vec![0, 7], vec![0.5, -1.0]), (vec![], vec![])],
        });
        round_trip(Frame::ScoreEx { gen: 3, flags: FLAG_DEGRADED, evaluated: 41, score: -0.75 });
        round_trip(Frame::ScoreBatchRespEx {
            gen: 4,
            flags: 0,
            results: vec![BatchResult { status: 0, evaluated: 12, score: 1.5 }],
        });
    }

    #[test]
    fn score_sparse_layout_is_exactly_as_documented() {
        let wire = Frame::ScoreSparse { gen: 2, idx: vec![5], val: vec![1.0] }.encode();
        // len = 1 (op) + 4 (gen) + 2 (nnz) + 10 (pair) = 17
        assert_eq!(&wire[0..4], &17u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_SPARSE);
        assert_eq!(&wire[5..9], &2u32.to_le_bytes());
        assert_eq!(&wire[9..11], &1u16.to_le_bytes());
        assert_eq!(&wire[11..13], &5u16.to_le_bytes());
        assert_eq!(&wire[13..21], &1.0f64.to_le_bytes());
        assert_eq!(wire.len(), 21);
    }

    #[test]
    fn truncated_frames_error() {
        let wire = Frame::Score { gen: 1, evaluated: 2, score: 3.0 }.encode();
        for cut in 0..wire.len() {
            let err = Frame::decode(&wire[..cut], MAX);
            assert!(err.is_err(), "decoding {cut}/{} bytes must fail", wire.len());
        }
        // Streaming: cut mid-body.
        let mut cursor = std::io::Cursor::new(&wire[..wire.len() - 1]);
        match Frame::read_from(&mut cursor, MAX) {
            Err(FrameError::Truncated(_)) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        // Streaming: clean close between frames is Eof.
        let mut empty = std::io::Cursor::new(Vec::<u8>::new());
        assert_eq!(Frame::read_from(&mut empty, MAX), Err(FrameError::Eof));
    }

    #[test]
    fn v3_frame_layouts_are_exactly_as_documented() {
        // SCORE_SPARSE2: 1 (op) + 2 (model) + 4 (gen) + 4 (nnz) + 12/pair.
        let wire =
            Frame::ScoreSparse2 { model: 7, gen: 2, idx: vec![70_000], val: vec![1.0] }.encode();
        assert_eq!(&wire[0..4], &23u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_SPARSE2);
        assert_eq!(&wire[5..7], &7u16.to_le_bytes());
        assert_eq!(&wire[7..11], &2u32.to_le_bytes());
        assert_eq!(&wire[11..15], &1u32.to_le_bytes());
        assert_eq!(&wire[15..19], &70_000u32.to_le_bytes());
        assert_eq!(&wire[19..27], &1.0f64.to_le_bytes());
        assert_eq!(wire.len(), 27);
        // SCORE_DENSE: 1 (op) + 2 (model) + 4 (gen) + 4 (count) + 8/value.
        let wire = Frame::ScoreDense { model: 1, gen: 3, val: vec![0.5, 0.25] }.encode();
        assert_eq!(&wire[0..4], &27u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_DENSE);
        assert_eq!(&wire[11..15], &2u32.to_le_bytes());
        assert_eq!(wire.len(), 31);
        // CLASS: 1 (op) + 4 + 8 + 4 + 4 + 4 = 25 body bytes.
        let wire =
            Frame::Class { gen: 1, label: 7, votes: 9, voters: 45, evaluated: 100 }.encode();
        assert_eq!(&wire[0..4], &25u32.to_le_bytes());
        assert_eq!(wire[4], OP_CLASS);
        assert_eq!(&wire[9..17], &7i64.to_le_bytes());
    }

    #[test]
    fn v3_layout_violations_are_rejected() {
        // Declared nnz larger than the carried pairs.
        let mut body = vec![OP_SCORE_SPARSE2];
        body.extend_from_slice(&0u16.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&5u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1.0f64.to_le_bytes());
        assert!(matches!(Frame::decode_body(&body), Err(FrameError::BadLayout(_))));
        // Dense count mismatch.
        let mut body = vec![OP_SCORE_DENSE];
        body.extend_from_slice(&0u16.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&3u32.to_le_bytes());
        body.extend_from_slice(&1.0f64.to_le_bytes());
        assert!(matches!(Frame::decode_body(&body), Err(FrameError::BadLayout(_))));
        // Truncated class response.
        assert!(matches!(
            Frame::decode_body(&[OP_CLASS, 0, 0, 0, 0]),
            Err(FrameError::BadLayout(_))
        ));
        // Short headers.
        assert!(Frame::decode_body(&[OP_SCORE_SPARSE2, 0, 0]).is_err());
        assert!(Frame::decode_body(&[OP_SCORE_DENSE, 0, 0]).is_err());
    }

    #[test]
    fn v7_frame_layouts_are_exactly_as_documented() {
        // SCORE_SPARSE_EX: 1 (op) + 2 (model) + 4 (gen) + 4 (deadline)
        // + 1 (lane) + 4 (nnz) + 12/pair.
        let wire = Frame::ScoreSparseEx {
            model: 7,
            gen: 2,
            deadline_ms: 250,
            lane: LANE_INTERACTIVE,
            idx: vec![70_000],
            val: vec![1.0],
        }
        .encode();
        assert_eq!(&wire[0..4], &28u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_SPARSE_EX);
        assert_eq!(&wire[5..7], &7u16.to_le_bytes());
        assert_eq!(&wire[7..11], &2u32.to_le_bytes());
        assert_eq!(&wire[11..15], &250u32.to_le_bytes());
        assert_eq!(wire[15], LANE_INTERACTIVE);
        assert_eq!(&wire[16..20], &1u32.to_le_bytes());
        assert_eq!(&wire[20..24], &70_000u32.to_le_bytes());
        assert_eq!(&wire[24..32], &1.0f64.to_le_bytes());
        assert_eq!(wire.len(), 32);
        // SCORE_BATCH_EX: 1 (op) + 2 (model) + 4 (gen) + 4 (deadline)
        // + 1 (lane) + 2 (count) + per-example nnz:u32 + 12/pair.
        let wire = Frame::ScoreBatchEx {
            model: 1,
            gen: 3,
            deadline_ms: 0,
            lane: LANE_BULK,
            examples: vec![(vec![5], vec![0.5])],
        }
        .encode();
        assert_eq!(&wire[0..4], &30u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_BATCH_EX);
        assert_eq!(&wire[11..15], &0u32.to_le_bytes());
        assert_eq!(wire[15], LANE_BULK);
        assert_eq!(&wire[16..18], &1u16.to_le_bytes());
        assert_eq!(&wire[18..22], &1u32.to_le_bytes());
        assert_eq!(wire.len(), 34);
        // SCORE_EX: 1 (op) + 4 (gen) + 1 (flags) + 4 (evaluated)
        // + 8 (score) = 18 body bytes.
        let wire =
            Frame::ScoreEx { gen: 9, flags: FLAG_DEGRADED, evaluated: 41, score: -0.75 }.encode();
        assert_eq!(&wire[0..4], &18u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_EX);
        assert_eq!(&wire[5..9], &9u32.to_le_bytes());
        assert_eq!(wire[9], FLAG_DEGRADED);
        assert_eq!(&wire[10..14], &41u32.to_le_bytes());
        assert_eq!(&wire[14..22], &(-0.75f64).to_le_bytes());
        // SCORE_BATCH_RESP_EX: 1 (op) + 4 (gen) + 1 (flags) + 2 (count)
        // + 13/row.
        let wire = Frame::ScoreBatchRespEx {
            gen: 6,
            flags: FLAG_DEGRADED,
            results: vec![BatchResult { status: 0, evaluated: 12, score: 1.5 }],
        }
        .encode();
        assert_eq!(&wire[0..4], &21u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_BATCH_RESP_EX);
        assert_eq!(wire[9], FLAG_DEGRADED);
        assert_eq!(&wire[10..12], &1u16.to_le_bytes());
        assert_eq!(wire[12], 0, "row status");
        assert_eq!(wire.len(), 25);
    }

    #[test]
    fn v7_layout_violations_are_rejected() {
        // A lane byte beyond LANE_BULK is structural damage, in both
        // decoders.
        let mut body = Frame::ScoreSparseEx {
            model: 0,
            gen: 0,
            deadline_ms: 0,
            lane: LANE_DEFAULT,
            idx: vec![1],
            val: vec![1.0],
        }
        .encode()[4..]
            .to_vec();
        // Body index 0 is the op byte, so the lane sits at 1 + 10 = 11.
        body[11] = 3;
        match Frame::decode_body(&body) {
            Err(FrameError::BadLayout(msg)) => assert!(msg.contains("lane"), "got {msg}"),
            other => panic!("expected BadLayout, got {other:?}"),
        }
        assert!(FrameRef::decode_borrowed(&body).is_err());
        let mut body = Frame::ScoreBatchEx {
            model: 0,
            gen: 0,
            deadline_ms: 0,
            lane: LANE_DEFAULT,
            examples: vec![],
        }
        .encode()[4..]
            .to_vec();
        body[11] = 0xFF;
        assert!(matches!(Frame::decode_body(&body), Err(FrameError::BadLayout(_))));
        assert!(FrameRef::decode_borrowed(&body).is_err());
        // nnz lying about the carried pairs.
        let mut body = Frame::ScoreSparseEx {
            model: 0,
            gen: 0,
            deadline_ms: 0,
            lane: LANE_DEFAULT,
            idx: vec![1],
            val: vec![1.0],
        }
        .encode()[4..]
            .to_vec();
        body[12..16].copy_from_slice(&9u32.to_le_bytes());
        match Frame::decode_body(&body) {
            Err(FrameError::BadLayout(msg)) => assert!(msg.contains("nnz"), "got {msg}"),
            other => panic!("expected BadLayout, got {other:?}"),
        }
        assert!(FrameRef::decode_borrowed(&body).is_err());
        // Short headers and exact-size responses.
        assert!(Frame::decode_body(&[OP_SCORE_SPARSE_EX, 0, 0, 0]).is_err());
        assert!(Frame::decode_body(&[OP_SCORE_BATCH_EX, 0, 0, 0]).is_err());
        assert!(Frame::decode_body(&[OP_SCORE_EX, 0, 0, 0, 0]).is_err());
        assert!(Frame::decode_body(&[OP_SCORE_BATCH_RESP_EX, 0, 0]).is_err());
        // Batch count overrunning the carried examples.
        let mut body = Frame::ScoreBatchEx {
            model: 0,
            gen: 0,
            deadline_ms: 0,
            lane: LANE_DEFAULT,
            examples: vec![(vec![1], vec![1.0])],
        }
        .encode()[4..]
            .to_vec();
        body[12..14].copy_from_slice(&2u16.to_le_bytes());
        match Frame::decode_body(&body) {
            Err(FrameError::BadLayout(msg)) => assert!(msg.contains("overruns"), "got {msg}"),
            other => panic!("expected BadLayout, got {other:?}"),
        }
        assert!(FrameRef::decode_borrowed(&body).is_err());
    }

    #[test]
    fn v7_incremental_encoders_match_owned_encoding() {
        // put_sparse_ex matches Frame::encode byte-for-byte.
        let frame = Frame::ScoreSparseEx {
            model: 3,
            gen: 8,
            deadline_ms: 125,
            lane: LANE_INTERACTIVE,
            idx: vec![2, 70_000],
            val: vec![0.5, -2.0],
        };
        let mut wire = Vec::new();
        Frame::put_sparse_ex(
            &mut wire,
            3,
            8,
            125,
            LANE_INTERACTIVE,
            &[2, 70_000],
            &[0.5, -2.0],
        );
        assert_eq!(wire, frame.encode());
        // begin_score_batch_ex + push_example + finish matches too.
        let examples = vec![(vec![0u32, 7], vec![0.5, -1.0]), (vec![], vec![])];
        let frame = Frame::ScoreBatchEx {
            model: 2,
            gen: 5,
            deadline_ms: 400,
            lane: LANE_BULK,
            examples: examples.clone(),
        };
        let mut wire = Vec::new();
        let mut enc = Frame::begin_score_batch_ex(&mut wire, 2, 5, 400, LANE_BULK);
        for (idx, val) in &examples {
            enc.push_example(idx, val);
        }
        assert_eq!(enc.finish(), examples.len());
        assert_eq!(wire, frame.encode());
        // begin_score_batch_resp_ex + push_result + finish.
        let results = vec![
            BatchResult { status: 0, evaluated: 12, score: 1.5 },
            BatchResult { status: 5, evaluated: 0, score: 0.0 },
        ];
        let frame =
            Frame::ScoreBatchRespEx { gen: 5, flags: FLAG_DEGRADED, results: results.clone() };
        let mut wire = Vec::new();
        let mut enc = Frame::begin_score_batch_resp_ex(&mut wire, 5, FLAG_DEGRADED);
        for r in &results {
            enc.push_result(r.status, r.evaluated, r.score);
        }
        assert_eq!(enc.finish(), results.len());
        assert_eq!(wire, frame.encode());
    }

    #[test]
    fn oversized_nnz_is_rejected() {
        // Declare 1000 pairs but carry one: layout error, not a panic or
        // a silent short read.
        let mut body = vec![OP_SCORE_SPARSE];
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&1000u16.to_le_bytes());
        body.extend_from_slice(&7u16.to_le_bytes());
        body.extend_from_slice(&1.0f64.to_le_bytes());
        match Frame::decode_body(&body) {
            Err(FrameError::BadLayout(msg)) => assert!(msg.contains("nnz"), "got {msg}"),
            other => panic!("expected BadLayout, got {other:?}"),
        }
    }

    #[test]
    fn length_cap_is_enforced() {
        let mut wire = Frame::JsonReq("x".repeat(100)).encode();
        match Frame::decode(&wire, 50) {
            Err(FrameError::TooLarge { len: 101, max: 50 }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // A hostile prefix claiming 4 GiB must be rejected before any
        // allocation happens.
        wire[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Frame::decode(&wire, MAX), Err(FrameError::TooLarge { .. })));
        let mut cursor = std::io::Cursor::new(&wire[..]);
        assert!(matches!(Frame::read_from(&mut cursor, MAX), Err(FrameError::TooLarge { .. })));
    }

    #[test]
    fn bad_ops_and_empty_frames_error() {
        assert_eq!(Frame::decode_body(&[]), Err(FrameError::Empty));
        assert_eq!(Frame::decode_body(&[0x7F]), Err(FrameError::BadOp(0x7F)));
        let empty = 0u32.to_le_bytes();
        assert_eq!(Frame::decode(&empty, MAX), Err(FrameError::Empty));
        assert!(Frame::decode_body(&[OP_ERROR, 99, 0, 0, 0]).is_err(), "bad error code");
        assert_eq!(Frame::decode_body(&[OP_JSON_REQ, 0xFF, 0xFE]), Err(FrameError::BadUtf8));
    }

    #[test]
    fn error_codes_round_trip_and_classify() {
        for code in [
            ErrorCode::BadFrame,
            ErrorCode::Overloaded,
            ErrorCode::DimMismatch,
            ErrorCode::NonFinite,
            ErrorCode::Unavailable,
            ErrorCode::StaleGeneration,
            ErrorCode::BadRequest,
            ErrorCode::UnknownModel,
            ErrorCode::WrongModel,
            ErrorCode::ModelExists,
            ErrorCode::ModelBusy,
            ErrorCode::DefaultModel,
            ErrorCode::Internal,
            ErrorCode::DeadlineExceeded,
        ] {
            assert_eq!(ErrorCode::from_u8(code as u8), Some(code));
            assert!(!code.name().is_empty());
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(200), None);
        assert!(ErrorCode::Overloaded.retryable());
        assert!(ErrorCode::StaleGeneration.retryable());
        assert!(!ErrorCode::DimMismatch.retryable());
        assert!(!ErrorCode::BadFrame.retryable());
        // Unknown stays non-retryable even with runtime registration:
        // the remover already drained the name, so a retry cannot see it
        // come back — only a fresh add-model (a new shard) could.
        assert!(!ErrorCode::UnknownModel.retryable());
        assert!(!ErrorCode::WrongModel.retryable());
        assert!(!ErrorCode::ModelExists.retryable());
        assert!(ErrorCode::ModelBusy.retryable(), "retry once the old name retires");
        assert!(!ErrorCode::DefaultModel.retryable());
        assert!(ErrorCode::Internal.retryable(), "a respawned worker can answer the retry");
        assert!(
            ErrorCode::DeadlineExceeded.retryable(),
            "a retry with a fresh deadline can land in a calmer queue"
        );
        assert_eq!(ErrorCode::DeadlineExceeded.name(), "deadline-exceeded");
    }

    #[test]
    fn verbose_classify_ops_round_trip() {
        round_trip(Frame::ClassifySparseVerbose {
            model: 2,
            gen: 4,
            idx: vec![5, 100_000],
            val: vec![1.0, 2.0],
        });
        round_trip(Frame::ClassVerbose {
            gen: 7,
            label: 2,
            votes: 2,
            voters: 3,
            evaluated: 120,
            per_voter: vec![
                VoterVote { pos: 1, neg: 2, vote: 2, features: 40 },
                VoterVote { pos: 1, neg: 3, vote: 1, features: 50 },
                VoterVote { pos: 2, neg: 3, vote: 2, features: 30 },
            ],
        });
        round_trip(Frame::ClassVerbose {
            gen: 1,
            label: 0,
            votes: 0,
            voters: 0,
            evaluated: 0,
            per_voter: vec![],
        });
        // CLASS_VERBOSE layout: 4 (len) + 1 (op) + 24 (class fields) +
        // 4 (count) + 28 per row.
        let wire = Frame::ClassVerbose {
            gen: 1,
            label: -5,
            votes: 1,
            voters: 1,
            evaluated: 9,
            per_voter: vec![VoterVote { pos: -5, neg: 8, vote: -5, features: 9 }],
        }
        .encode();
        assert_eq!(&wire[0..4], &57u32.to_le_bytes());
        assert_eq!(wire[4], OP_CLASS_VERBOSE);
        assert_eq!(&wire[9..17], &(-5i64).to_le_bytes());
        assert_eq!(&wire[29..33], &1u32.to_le_bytes(), "row count");
        assert_eq!(&wire[33..41], &(-5i64).to_le_bytes(), "row pos");
        assert_eq!(wire.len(), 61);
        // Row-count mismatches are layout errors.
        let mut bad = wire[4..wire.len() - 1].to_vec();
        bad[25..29].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(Frame::decode_body(&bad), Err(FrameError::BadLayout(_))));
    }

    #[test]
    fn learn_ops_round_trip_with_documented_layout() {
        round_trip(Frame::LearnSparse {
            model: 3,
            label: -1,
            idx: vec![0, 70_000, 4_000_000_000],
            val: vec![0.25, -1.5, 1.0],
        });
        round_trip(Frame::LearnSparse { model: 0, label: 1, idx: vec![], val: vec![] });
        round_trip(Frame::LearnAck { gen: 9, seen: u64::MAX });
        round_trip(Frame::LearnAck { gen: 0, seen: 0 });
        // LEARN_SPARSE: 1 (op) + 2 (model) + 1 (label) + 4 (nnz) + 12/pair.
        let wire =
            Frame::LearnSparse { model: 7, label: -1, idx: vec![70_000], val: vec![1.0] }.encode();
        assert_eq!(&wire[0..4], &20u32.to_le_bytes());
        assert_eq!(wire[4], OP_LEARN_SPARSE);
        assert_eq!(&wire[5..7], &7u16.to_le_bytes());
        assert_eq!(wire[7] as i8, -1);
        assert_eq!(&wire[8..12], &1u32.to_le_bytes());
        assert_eq!(&wire[12..16], &70_000u32.to_le_bytes());
        assert_eq!(&wire[16..24], &1.0f64.to_le_bytes());
        assert_eq!(wire.len(), 24);
        // LEARN_ACK: 1 (op) + 4 (gen) + 8 (seen).
        let wire = Frame::LearnAck { gen: 5, seen: 1234 }.encode();
        assert_eq!(&wire[0..4], &13u32.to_le_bytes());
        assert_eq!(wire[4], OP_LEARN_ACK);
        assert_eq!(&wire[5..9], &5u32.to_le_bytes());
        assert_eq!(&wire[9..17], &1234u64.to_le_bytes());
        // The slice encoder matches the owned encoder.
        let idx = vec![3u32, 17, 40];
        let val = vec![0.5, -1.2, 2.0];
        let mut out = Vec::new();
        Frame::put_learn_sparse(&mut out, 5, 1, &idx, &val);
        let owned =
            Frame::LearnSparse { model: 5, label: 1, idx: idx.clone(), val: val.clone() }.encode();
        assert_eq!(out, owned);
    }

    #[test]
    fn learn_layout_violations_are_rejected() {
        // Bad label byte (0) — both decoders must refuse.
        let mut body = vec![OP_LEARN_SPARSE];
        body.extend_from_slice(&0u16.to_le_bytes());
        body.push(0);
        body.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(Frame::decode_body(&body), Err(FrameError::BadLayout(_))));
        assert!(matches!(FrameRef::decode_borrowed(&body), Err(FrameError::BadLayout(_))));
        // nnz declaring more pairs than carried.
        let mut body = vec![OP_LEARN_SPARSE];
        body.extend_from_slice(&0u16.to_le_bytes());
        body.push(1);
        body.extend_from_slice(&5u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1.0f64.to_le_bytes());
        assert!(matches!(Frame::decode_body(&body), Err(FrameError::BadLayout(_))));
        assert!(matches!(FrameRef::decode_borrowed(&body), Err(FrameError::BadLayout(_))));
        // Short header.
        assert!(Frame::decode_body(&[OP_LEARN_SPARSE, 0, 0]).is_err());
        assert!(FrameRef::decode_borrowed(&[OP_LEARN_SPARSE, 0, 0]).is_err());
        // Truncated ack.
        assert!(matches!(
            Frame::decode_body(&[OP_LEARN_ACK, 0, 0, 0, 0]),
            Err(FrameError::BadLayout(_))
        ));
    }

    #[test]
    fn borrowed_decode_matches_owned_decode() {
        let frames = vec![
            Frame::ScoreSparse { gen: 7, idx: vec![0, 13, 783], val: vec![0.25, -1.5, 1.0] },
            Frame::ScoreSparse { gen: 0, idx: vec![], val: vec![] },
            Frame::JsonReq(r#"{"op":"stats"}"#.into()),
            Frame::ScoreDense { model: 3, gen: 2, val: vec![0.5, -1.0, 0.0] },
            Frame::ScoreSparse2 {
                model: 1,
                gen: 9,
                idx: vec![0, 70_000, 4_000_000_000],
                val: vec![0.25, -1.5, 1.0],
            },
            Frame::ClassifySparse { model: 2, gen: 4, idx: vec![5, 100_000], val: vec![1.0, 2.0] },
            Frame::ClassifySparseVerbose { model: 2, gen: 4, idx: vec![5], val: vec![1.0] },
            Frame::LearnSparse { model: 4, label: -1, idx: vec![5, 100_000], val: vec![1.0, 2.0] },
            Frame::LearnSparse { model: 0, label: 1, idx: vec![], val: vec![] },
            Frame::ScoreBatch {
                model: 1,
                gen: 3,
                examples: vec![
                    (vec![0, 70_000], vec![0.5, -1.5]),
                    (vec![], vec![]),
                    (vec![7], vec![2.0]),
                ],
            },
            Frame::ScoreBatch { model: 0, gen: 0, examples: vec![] },
            Frame::ScoreSparseEx {
                model: 1,
                gen: 9,
                deadline_ms: 250,
                lane: LANE_INTERACTIVE,
                idx: vec![0, 70_000, 4_000_000_000],
                val: vec![0.25, -1.5, 1.0],
            },
            Frame::ScoreBatchEx {
                model: 2,
                gen: 5,
                deadline_ms: 1_000,
                lane: LANE_BULK,
                examples: vec![(vec![0, 7], vec![0.5, -1.0]), (vec![], vec![])],
            },
            Frame::ScoreBatchEx {
                model: 0,
                gen: 0,
                deadline_ms: 0,
                lane: LANE_DEFAULT,
                examples: vec![],
            },
        ];
        for frame in frames {
            let wire = frame.encode();
            let body = &wire[4..];
            let borrowed = FrameRef::decode_borrowed(body).expect("borrowed decode");
            // The borrowed view reconstructs the exact owned frame.
            let rebuilt = match borrowed {
                FrameRef::ScoreSparse { gen, pairs } => {
                    validate_pairs_u16(pairs).unwrap();
                    let Features::Sparse { idx, val } = pairs_to_features_u16(pairs) else {
                        unreachable!()
                    };
                    assert_eq!(borrowed.nnz(), idx.len());
                    Frame::ScoreSparse {
                        gen,
                        idx: idx.into_iter().map(|i| i as u16).collect(),
                        val,
                    }
                }
                FrameRef::JsonReq(doc) => Frame::JsonReq(doc.to_string()),
                FrameRef::ScoreDense { model, gen, vals } => {
                    validate_dense_vals(vals).unwrap();
                    let Features::Dense(val) = dense_to_features(vals) else { unreachable!() };
                    Frame::ScoreDense { model, gen, val }
                }
                FrameRef::ScoreSparse2 { model, gen, pairs } => {
                    validate_pairs_u32(pairs).unwrap();
                    let Features::Sparse { idx, val } = pairs_to_features_u32(pairs) else {
                        unreachable!()
                    };
                    Frame::ScoreSparse2 { model, gen, idx, val }
                }
                FrameRef::ClassifySparse { model, gen, pairs, verbose } => {
                    validate_pairs_u32(pairs).unwrap();
                    let Features::Sparse { idx, val } = pairs_to_features_u32(pairs) else {
                        unreachable!()
                    };
                    if verbose {
                        Frame::ClassifySparseVerbose { model, gen, idx, val }
                    } else {
                        Frame::ClassifySparse { model, gen, idx, val }
                    }
                }
                FrameRef::LearnSparse { model, label, pairs } => {
                    validate_pairs_u32(pairs).unwrap();
                    let Features::Sparse { idx, val } = pairs_to_features_u32(pairs) else {
                        unreachable!()
                    };
                    assert_eq!(borrowed.nnz(), idx.len());
                    Frame::LearnSparse { model, label, idx, val }
                }
                FrameRef::ScoreBatch { model, gen, count, examples } => {
                    let mut rebuilt = Vec::with_capacity(count);
                    for pairs in batch_pairs(examples) {
                        validate_pairs_u32(pairs).unwrap();
                        let Features::Sparse { idx, val } = pairs_to_features_u32(pairs) else {
                            unreachable!()
                        };
                        rebuilt.push((idx, val));
                    }
                    assert_eq!(rebuilt.len(), count, "iterator yields every example");
                    assert_eq!(
                        borrowed.nnz(),
                        rebuilt.iter().map(|(idx, _)| idx.len()).sum::<usize>(),
                        "batch nnz sums across examples"
                    );
                    Frame::ScoreBatch { model, gen, examples: rebuilt }
                }
                FrameRef::ScoreSparseEx { model, gen, deadline_ms, lane, pairs } => {
                    validate_pairs_u32(pairs).unwrap();
                    let Features::Sparse { idx, val } = pairs_to_features_u32(pairs) else {
                        unreachable!()
                    };
                    assert_eq!(borrowed.nnz(), idx.len());
                    Frame::ScoreSparseEx { model, gen, deadline_ms, lane, idx, val }
                }
                FrameRef::ScoreBatchEx { model, gen, deadline_ms, lane, count, examples } => {
                    let mut rebuilt = Vec::with_capacity(count);
                    for pairs in batch_pairs(examples) {
                        validate_pairs_u32(pairs).unwrap();
                        let Features::Sparse { idx, val } = pairs_to_features_u32(pairs) else {
                            unreachable!()
                        };
                        rebuilt.push((idx, val));
                    }
                    assert_eq!(rebuilt.len(), count, "iterator yields every example");
                    Frame::ScoreBatchEx { model, gen, deadline_ms, lane, examples: rebuilt }
                }
                FrameRef::Response(op) => panic!("request decoded as response {op:#04x}"),
            };
            assert_eq!(rebuilt, frame);
        }
        // Response ops surface as Response without a payload decode.
        let wire = Frame::Score { gen: 1, evaluated: 2, score: 3.0 }.encode();
        assert_eq!(FrameRef::decode_borrowed(&wire[4..]), Ok(FrameRef::Response(OP_SCORE)));
        let wire = Frame::LearnAck { gen: 1, seen: 2 }.encode();
        assert_eq!(FrameRef::decode_borrowed(&wire[4..]), Ok(FrameRef::Response(OP_LEARN_ACK)));
        let wire = Frame::ScoreBatchResp {
            gen: 1,
            results: vec![BatchResult { status: 0, evaluated: 2, score: 3.0 }],
        }
        .encode();
        assert_eq!(
            FrameRef::decode_borrowed(&wire[4..]),
            Ok(FrameRef::Response(OP_SCORE_BATCH_RESP))
        );
        let wire = Frame::ScoreEx { gen: 1, flags: FLAG_DEGRADED, evaluated: 2, score: 3.0 }
            .encode();
        assert_eq!(FrameRef::decode_borrowed(&wire[4..]), Ok(FrameRef::Response(OP_SCORE_EX)));
        let wire = Frame::ScoreBatchRespEx { gen: 1, flags: 0, results: vec![] }.encode();
        assert_eq!(
            FrameRef::decode_borrowed(&wire[4..]),
            Ok(FrameRef::Response(OP_SCORE_BATCH_RESP_EX))
        );
        // And both decoders agree on rejects.
        assert!(FrameRef::decode_borrowed(&[]).is_err());
        assert!(FrameRef::decode_borrowed(&[0x7F]).is_err());
        let mut bad = Frame::ScoreSparse2 { model: 0, gen: 0, idx: vec![1], val: vec![1.0] }
            .encode()[4..]
            .to_vec();
        bad[7..11].copy_from_slice(&9u32.to_le_bytes()); // nnz lies
        assert!(FrameRef::decode_borrowed(&bad).is_err());
        assert!(Frame::decode_body(&bad).is_err());
    }

    #[test]
    fn in_place_validators_reject_structural_damage() {
        let enc = |idx: &[u32], val: &[f64]| {
            let mut out = Vec::new();
            Frame::put_sparse_v3(&mut out, OP_SCORE_SPARSE2, 0, 0, idx, val);
            out[4 + 1 + 2 + 4 + 4..].to_vec() // pair bytes only
        };
        assert!(validate_pairs_u32(&enc(&[1, 5, 9], &[1.0, 2.0, 3.0])).is_ok());
        assert!(validate_pairs_u32(&enc(&[], &[])).is_ok());
        assert_eq!(
            validate_pairs_u32(&enc(&[5, 2], &[1.0, 1.0])),
            Err("sparse idx must be strictly increasing")
        );
        assert_eq!(
            validate_pairs_u32(&enc(&[2, 2], &[1.0, 1.0])),
            Err("sparse idx must be strictly increasing")
        );
        assert_eq!(
            validate_pairs_u32(&enc(&[1], &[f64::NAN])),
            Err("non-finite feature value")
        );
        // u16 flavor.
        let enc16 = |idx: &[u32], val: &[f64]| {
            let mut out = Vec::new();
            Frame::put_score_sparse(&mut out, 0, idx, val).unwrap();
            out[4 + 1 + 4 + 2..].to_vec()
        };
        assert!(validate_pairs_u16(&enc16(&[1, 5], &[1.0, 2.0])).is_ok());
        assert!(validate_pairs_u16(&enc16(&[5, 1], &[1.0, 2.0])).is_err());
        assert!(validate_pairs_u16(&enc16(&[1], &[f64::INFINITY])).is_err());
        // Dense finiteness.
        let dense: Vec<u8> = [1.0f64, f64::NAN].iter().flat_map(|v| v.to_le_bytes()).collect();
        assert!(validate_dense_vals(&dense).is_err());
        let dense: Vec<u8> = [1.0f64, -2.5].iter().flat_map(|v| v.to_le_bytes()).collect();
        assert!(validate_dense_vals(&dense).is_ok());
    }

    #[test]
    fn slice_encoders_match_frame_encoders() {
        let idx = vec![3u32, 17, 40];
        let val = vec![0.5, -1.2, 2.0];
        let mut out = Vec::new();
        Frame::put_score_sparse(&mut out, 9, &idx, &val).unwrap();
        let owned = Frame::ScoreSparse {
            gen: 9,
            idx: idx.iter().map(|&i| i as u16).collect(),
            val: val.clone(),
        }
        .encode();
        assert_eq!(out, owned);
        // Out-of-bound index is an error, not truncation.
        let mut scratch = Vec::new();
        assert!(Frame::put_score_sparse(&mut scratch, 0, &[70_000], &[1.0]).is_err());

        for (op, owned) in [
            (
                OP_SCORE_SPARSE2,
                Frame::ScoreSparse2 { model: 5, gen: 2, idx: idx.clone(), val: val.clone() },
            ),
            (
                OP_CLASSIFY_SPARSE,
                Frame::ClassifySparse { model: 5, gen: 2, idx: idx.clone(), val: val.clone() },
            ),
            (
                OP_CLASSIFY_SPARSE_VERBOSE,
                Frame::ClassifySparseVerbose {
                    model: 5,
                    gen: 2,
                    idx: idx.clone(),
                    val: val.clone(),
                },
            ),
        ] {
            let mut out = Vec::new();
            Frame::put_sparse_v3(&mut out, op, 5, 2, &idx, &val);
            assert_eq!(out, owned.encode(), "op {op:#04x}");
        }
        // encode_into appends (batching many frames into one buffer).
        let mut batch = Vec::new();
        Frame::Score { gen: 1, evaluated: 2, score: 3.0 }.encode_into(&mut batch);
        let first_len = batch.len();
        Frame::Score { gen: 4, evaluated: 5, score: 6.0 }.encode_into(&mut batch);
        let (a, used) = Frame::decode(&batch, MAX).unwrap();
        assert_eq!(used, first_len);
        assert_eq!(a, Frame::Score { gen: 1, evaluated: 2, score: 3.0 });
        let (b, _) = Frame::decode(&batch[used..], MAX).unwrap();
        assert_eq!(b, Frame::Score { gen: 4, evaluated: 5, score: 6.0 });
    }

    #[test]
    fn batch_ops_round_trip_with_documented_layout() {
        round_trip(Frame::ScoreBatch {
            model: 3,
            gen: 9,
            examples: vec![
                (vec![0, 70_000, 4_000_000_000], vec![0.25, -1.5, 1.0]),
                (vec![], vec![]),
                (vec![13], vec![-2.0]),
            ],
        });
        round_trip(Frame::ScoreBatch { model: 0, gen: 0, examples: vec![] });
        round_trip(Frame::ScoreBatchResp {
            gen: 7,
            results: vec![
                BatchResult { status: BATCH_STATUS_OK, evaluated: 41, score: -0.75 },
                BatchResult { status: ErrorCode::DimMismatch as u8, evaluated: 0, score: 0.0 },
                BatchResult { status: BATCH_STATUS_OK, evaluated: 9, score: 2.5 },
            ],
        });
        round_trip(Frame::ScoreBatchResp { gen: 0, results: vec![] });
        // SCORE_BATCH: 1 (op) + 2 (model) + 4 (gen) + 2 (count), then
        // per example 4 (nnz) + 12/pair.
        let wire = Frame::ScoreBatch {
            model: 7,
            gen: 2,
            examples: vec![(vec![70_000], vec![1.0])],
        }
        .encode();
        assert_eq!(&wire[0..4], &25u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_BATCH);
        assert_eq!(&wire[5..7], &7u16.to_le_bytes());
        assert_eq!(&wire[7..11], &2u32.to_le_bytes());
        assert_eq!(&wire[11..13], &1u16.to_le_bytes());
        assert_eq!(&wire[13..17], &1u32.to_le_bytes());
        assert_eq!(&wire[17..21], &70_000u32.to_le_bytes());
        assert_eq!(&wire[21..29], &1.0f64.to_le_bytes());
        assert_eq!(wire.len(), 29);
        // SCORE_BATCH_RESP: 1 (op) + 4 (gen) + 2 (count) + 13/row.
        let wire = Frame::ScoreBatchResp {
            gen: 5,
            results: vec![BatchResult { status: 0, evaluated: 9, score: -0.5 }],
        }
        .encode();
        assert_eq!(&wire[0..4], &20u32.to_le_bytes());
        assert_eq!(wire[4], OP_SCORE_BATCH_RESP);
        assert_eq!(&wire[5..9], &5u32.to_le_bytes());
        assert_eq!(&wire[9..11], &1u16.to_le_bytes());
        assert_eq!(wire[11], 0);
        assert_eq!(&wire[12..16], &9u32.to_le_bytes());
        assert_eq!(&wire[16..24], &(-0.5f64).to_le_bytes());
        assert_eq!(wire.len(), 24);
    }

    #[test]
    fn batch_encoders_match_frame_encoders() {
        // Request builder, appended after existing buffer content so the
        // placeholder patching is exercised at a nonzero offset.
        let mut out = Vec::new();
        Frame::Score { gen: 1, evaluated: 2, score: 3.0 }.encode_into(&mut out);
        let base = out.len();
        let mut enc = Frame::begin_score_batch(&mut out, 5, 2);
        enc.push_example(&[3, 17, 40], &[0.5, -1.2, 2.0]);
        enc.push_example(&[], &[]);
        assert_eq!(enc.finish(), 2);
        let owned = Frame::ScoreBatch {
            model: 5,
            gen: 2,
            examples: vec![(vec![3, 17, 40], vec![0.5, -1.2, 2.0]), (vec![], vec![])],
        }
        .encode();
        assert_eq!(&out[base..], &owned[..]);
        // Response builder.
        let mut out = Vec::new();
        let mut enc = Frame::begin_score_batch_resp(&mut out, 9);
        enc.push_result(BATCH_STATUS_OK, 7, 1.25);
        enc.push_result(ErrorCode::NonFinite as u8, 0, 0.0);
        assert_eq!(enc.finish(), 2);
        let owned = Frame::ScoreBatchResp {
            gen: 9,
            results: vec![
                BatchResult { status: BATCH_STATUS_OK, evaluated: 7, score: 1.25 },
                BatchResult { status: ErrorCode::NonFinite as u8, evaluated: 0, score: 0.0 },
            ],
        }
        .encode();
        assert_eq!(out, owned);
        // An empty batch still produces a decodable frame.
        let mut out = Vec::new();
        let enc = Frame::begin_score_batch(&mut out, 0, 0);
        assert_eq!(enc.finish(), 0);
        let (frame, used) = Frame::decode(&out, MAX).unwrap();
        assert_eq!(used, out.len());
        assert_eq!(frame, Frame::ScoreBatch { model: 0, gen: 0, examples: vec![] });
    }

    #[test]
    fn batch_layout_violations_are_rejected() {
        let body_of = |frame: &Frame| frame.encode()[4..].to_vec();
        let good = Frame::ScoreBatch {
            model: 0,
            gen: 0,
            examples: vec![(vec![1], vec![1.0]), (vec![2], vec![2.0])],
        };
        // Count declares more examples than carried.
        let mut bad = body_of(&good);
        bad[7..9].copy_from_slice(&3u16.to_le_bytes());
        assert!(matches!(Frame::decode_body(&bad), Err(FrameError::BadLayout(_))));
        assert!(matches!(FrameRef::decode_borrowed(&bad), Err(FrameError::BadLayout(_))));
        // Count declares fewer: trailing bytes are an error, not
        // silently ignored payload.
        let mut bad = body_of(&good);
        bad[7..9].copy_from_slice(&1u16.to_le_bytes());
        assert!(matches!(Frame::decode_body(&bad), Err(FrameError::BadLayout(_))));
        assert!(matches!(FrameRef::decode_borrowed(&bad), Err(FrameError::BadLayout(_))));
        // An example's nnz overruns the frame.
        let mut bad = body_of(&good);
        bad[9..13].copy_from_slice(&1000u32.to_le_bytes());
        assert!(matches!(Frame::decode_body(&bad), Err(FrameError::BadLayout(_))));
        assert!(matches!(FrameRef::decode_borrowed(&bad), Err(FrameError::BadLayout(_))));
        // Short header.
        assert!(Frame::decode_body(&[OP_SCORE_BATCH, 0, 0]).is_err());
        assert!(FrameRef::decode_borrowed(&[OP_SCORE_BATCH, 0, 0]).is_err());
        // Response: row-count mismatch and short header.
        let resp = Frame::ScoreBatchResp {
            gen: 1,
            results: vec![BatchResult { status: 0, evaluated: 1, score: 1.0 }],
        };
        let mut bad = body_of(&resp);
        bad[5..7].copy_from_slice(&4u16.to_le_bytes());
        assert!(matches!(Frame::decode_body(&bad), Err(FrameError::BadLayout(_))));
        assert!(Frame::decode_body(&[OP_SCORE_BATCH_RESP, 0, 0]).is_err());
    }

    #[test]
    fn read_body_reuses_the_buffer() {
        let mut wire = Vec::new();
        Frame::Score { gen: 1, evaluated: 2, score: 3.0 }.encode_into(&mut wire);
        Frame::Score { gen: 7, evaluated: 8, score: 9.0 }.encode_into(&mut wire);
        let mut cursor = std::io::Cursor::new(&wire[..]);
        let mut body = Vec::new();
        Frame::read_body(&mut cursor, &mut body, MAX).unwrap();
        let cap = body.capacity();
        assert_eq!(
            Frame::decode_body(&body).unwrap(),
            Frame::Score { gen: 1, evaluated: 2, score: 3.0 }
        );
        Frame::read_body(&mut cursor, &mut body, MAX).unwrap();
        assert_eq!(body.capacity(), cap, "second same-size read must not reallocate");
        assert_eq!(
            Frame::decode_body(&body).unwrap(),
            Frame::Score { gen: 7, evaluated: 8, score: 9.0 }
        );
        assert_eq!(Frame::read_body(&mut cursor, &mut body, MAX), Err(FrameError::Eof));
    }
}
