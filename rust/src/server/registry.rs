//! [`ModelRegistry`]: a named collection of independently hot-reloadable
//! model shards behind one serving port.
//!
//! Each shard is a [`ModelHub`] — it keeps the hub's generation-pinning
//! and drain-on-swap semantics — hosting either a binary model or an
//! all-pairs multiclass ensemble ([`ServingModel`]). The shard set is
//! fixed at startup (`serve --model name=path`, repeatable), which makes
//! routing lock-free: resolving a route only reads an immutable name
//! table, and a hot reload of one shard contends only on that shard's
//! internal mutex — **a reload of one model can never stall traffic on
//! another**.
//!
//! The first registered shard is the **default shard** (wire model id
//! 0): it answers every request that does not name a model, which is how
//! v1 single-model clients keep working unmodified against a multi-model
//! server. On the wire, shards are addressed by name (JSON `"model"`
//! field) or by the interned `u16` id the registry assigns at
//! registration (binary v3 frames); the `models` op lists the table.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::TrainerWireConfig;
use crate::coordinator::online::{LearnError, OnlineTrainer, TrainerStatsSnapshot};
use crate::coordinator::service::{CompletionNotifier, Features, ServingModel, StatsSnapshot};
use crate::error::{Error, Result};
use crate::server::hub::{HubError, HubInfo, ModelHub};

/// Name of the shard that answers un-routed (single-model) requests
/// when none is given explicitly at registration time.
pub const DEFAULT_MODEL: &str = "default";

/// Why the registry could not route a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    /// No shard with that name.
    UnknownName(String),
    /// No shard with that wire id.
    UnknownId(u16),
    /// The shard rejected the request (shed, kind/dim mismatch, ...).
    Hub(HubError),
    /// A `learn` was routed to a shard with no online trainer attached.
    NoTrainer(String),
    /// The shard's learn queue was full; the example was shed. Retryable.
    LearnShed,
    /// The shard's trainer has shut down.
    TrainerClosed,
}

impl From<HubError> for RegistryError {
    fn from(e: HubError) -> Self {
        RegistryError::Hub(e)
    }
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::UnknownName(name) => write!(f, "unknown model {name:?}"),
            RegistryError::UnknownId(id) => write!(f, "unknown model id {id}"),
            RegistryError::Hub(e) => write!(f, "{e}"),
            RegistryError::NoTrainer(name) => {
                write!(f, "model {name:?} has no online trainer attached")
            }
            RegistryError::LearnShed => write!(f, "overloaded"),
            RegistryError::TrainerClosed => write!(f, "trainer closed"),
        }
    }
}

/// One serving shard: a named, independently reloadable [`ModelHub`],
/// optionally fed by a background [`OnlineTrainer`] that publishes
/// fresh snapshot generations into the hub.
struct Shard {
    name: String,
    /// Shared so an attached trainer can publish into the hub's
    /// generation swap from its own thread.
    hub: Arc<ModelHub>,
    trainer: Option<OnlineTrainer>,
}

impl Shard {
    /// Route one labeled example to this shard's trainer. Returns
    /// `(serving generation, cumulative accepted examples)` for the ack.
    fn learn(&self, features: Features, label: f64) -> std::result::Result<(u32, u64), RegistryError> {
        let trainer =
            self.trainer.as_ref().ok_or_else(|| RegistryError::NoTrainer(self.name.clone()))?;
        // Same dimension screen the score path applies at admission: a
        // bad payload must never reach the trainer thread.
        if let Err((expected, got)) = features.check_dim(self.hub.dim()) {
            return Err(RegistryError::Hub(HubError::DimMismatch { expected, got }));
        }
        let seen = trainer.learn(features, label).map_err(|e| match e {
            LearnError::Shed => RegistryError::LearnShed,
            LearnError::Closed => RegistryError::TrainerClosed,
        })?;
        Ok((self.hub.generation(), seen))
    }
}

/// A shard's identity and live serving state, as listed by the `models`
/// op.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    /// Shard name (the JSON routing key).
    pub name: String,
    /// Interned wire id (the binary-frame routing key; 0 = default).
    pub id: u16,
    /// Live serving state (generation, dim, kind, voters).
    pub hub: HubInfo,
    /// Hot reloads applied to this shard.
    pub reloads: u64,
    /// Whether an online trainer is attached (the shard accepts `learn`).
    pub learn: bool,
}

/// Per-shard slice of the `stats` op.
#[derive(Debug, Clone)]
pub struct ShardStats {
    /// Shard name.
    pub name: String,
    /// This shard's aggregated service counters.
    pub stats: StatsSnapshot,
    /// Serving generation.
    pub gen: u32,
    /// Hot reloads applied.
    pub reloads: u64,
    /// Trainer counters, when an online trainer is attached.
    pub trainer: Option<TrainerStatsSnapshot>,
}

/// A named collection of independently hot-reloadable model shards.
pub struct ModelRegistry {
    /// Index = interned wire id. Immutable after construction: routing
    /// never takes a registry-wide lock.
    shards: Vec<Shard>,
    by_name: HashMap<String, u16>,
}

impl ModelRegistry {
    /// Build the registry, spawning one hub per `(name, model)` pair.
    /// The first entry becomes the default shard (wire id 0). Names
    /// must be unique and non-empty; at most `u16::MAX + 1` shards.
    pub fn new(
        models: Vec<(String, ServingModel)>,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
    ) -> Result<Self> {
        Self::new_with_notifier(models, max_batch, queue, workers, seed, CompletionNotifier::default())
    }

    /// [`Self::new`] with a worker-completion notifier, fired by every
    /// shard's workers after each response send (the event-loop backend
    /// uses it to wake its pollers instead of tick-polling).
    pub fn new_with_notifier(
        models: Vec<(String, ServingModel)>,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
        notifier: CompletionNotifier,
    ) -> Result<Self> {
        if models.is_empty() {
            return Err(Error::Config("registry needs at least one model shard".into()));
        }
        if models.len() > u16::MAX as usize + 1 {
            return Err(Error::Config(format!(
                "registry holds at most {} shards, got {}",
                u16::MAX as usize + 1,
                models.len()
            )));
        }
        let mut shards = Vec::with_capacity(models.len());
        let mut by_name = HashMap::with_capacity(models.len());
        for (i, (name, model)) in models.into_iter().enumerate() {
            if name.is_empty() {
                return Err(Error::Config("model shard name must not be empty".into()));
            }
            if by_name.insert(name.clone(), i as u16).is_some() {
                return Err(Error::Config(format!("duplicate model shard name {name:?}")));
            }
            // One seed stream per shard, so co-hosted shards never share
            // a policy RNG sequence.
            let shard_seed = seed ^ (i as u64).wrapping_mul(0xA076_1D64_78BD_642F);
            shards.push(Shard {
                name,
                hub: Arc::new(ModelHub::new_with_notifier(
                    model,
                    max_batch,
                    queue,
                    workers,
                    shard_seed,
                    notifier.clone(),
                )),
                trainer: None,
            });
        }
        Ok(Self { shards, by_name })
    }

    /// Attach an online trainer to one shard (`None` = the default
    /// shard): a background thread that consumes `learn` examples and
    /// periodically publishes snapshots into the shard's hub. Fails on
    /// ensemble shards (the trainer publishes binary snapshots) and on
    /// shards that already have a trainer.
    pub fn attach_trainer(&mut self, name: Option<&str>, cfg: &TrainerWireConfig) -> Result<()> {
        let id = match name {
            None => 0u16,
            Some(n) => *self
                .by_name
                .get(n)
                .ok_or_else(|| Error::Config(format!("unknown model shard {n:?}")))?,
        };
        let shard = &mut self.shards[id as usize];
        let info = shard.hub.info();
        if info.kind != "binary" {
            return Err(Error::Config(format!(
                "online trainer needs a binary shard, {:?} serves {}",
                shard.name, info.kind
            )));
        }
        if shard.trainer.is_some() {
            return Err(Error::Config(format!(
                "model shard {:?} already has a trainer",
                shard.name
            )));
        }
        shard.trainer = Some(OnlineTrainer::spawn(Arc::clone(&shard.hub), cfg, info.dim));
        Ok(())
    }

    /// Route one labeled example by optional shard name (`None` = the
    /// default shard). Returns `(serving generation, examples seen)`.
    pub fn learn(
        &self,
        name: Option<&str>,
        features: Features,
        label: f64,
    ) -> std::result::Result<(u32, u64), RegistryError> {
        let shard = match name {
            None => &self.shards[0],
            Some(n) => {
                let &id = self
                    .by_name
                    .get(n)
                    .ok_or_else(|| RegistryError::UnknownName(n.to_string()))?;
                &self.shards[id as usize]
            }
        };
        shard.learn(features, label)
    }

    /// Route one labeled example by interned wire id (binary
    /// `LEARN_SPARSE` frames; id 0 = default shard).
    pub fn learn_by_id(
        &self,
        id: u16,
        features: Features,
        label: f64,
    ) -> std::result::Result<(u32, u64), RegistryError> {
        let shard = self.shards.get(id as usize).ok_or(RegistryError::UnknownId(id))?;
        shard.learn(features, label)
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// True when the registry holds no shards (never, post-construction;
    /// kept for API completeness).
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// The default shard's hub (wire id 0).
    pub fn default_hub(&self) -> &ModelHub {
        &*self.shards[0].hub
    }

    /// Whether the shard routed by `name` has a trainer attached.
    pub fn has_trainer(&self, name: Option<&str>) -> bool {
        match name {
            None => self.shards[0].trainer.is_some(),
            Some(n) => self
                .by_name
                .get(n)
                .is_some_and(|&id| self.shards[id as usize].trainer.is_some()),
        }
    }

    /// Route by optional name: `None` (and the default shard's own
    /// name) lands on the default shard. Returns the interned id with
    /// the hub so binary responses can be stamped.
    pub fn resolve_name(&self, name: Option<&str>) -> std::result::Result<(u16, &ModelHub), RegistryError> {
        match name {
            None => Ok((0, &*self.shards[0].hub)),
            Some(name) => {
                let &id = self
                    .by_name
                    .get(name)
                    .ok_or_else(|| RegistryError::UnknownName(name.to_string()))?;
                Ok((id, &*self.shards[id as usize].hub))
            }
        }
    }

    /// Route by interned wire id (binary v3 frames; id 0 = default).
    pub fn resolve_id(&self, id: u16) -> std::result::Result<&ModelHub, RegistryError> {
        self.shards.get(id as usize).map(|s| &*s.hub).ok_or(RegistryError::UnknownId(id))
    }

    /// Hot-swap one shard's model (`None` routes to the default shard).
    /// Only that shard's hub mutex is touched; every other shard keeps
    /// serving untouched.
    pub fn reload(
        &self,
        name: Option<&str>,
        model: ServingModel,
    ) -> std::result::Result<usize, RegistryError> {
        let (_, hub) = self.resolve_name(name)?;
        hub.reload(model).map_err(RegistryError::Hub)
    }

    /// Identity + live state of every shard, in wire-id order.
    pub fn infos(&self) -> Vec<ShardInfo> {
        self.shards
            .iter()
            .enumerate()
            .map(|(id, s)| ShardInfo {
                name: s.name.clone(),
                id: id as u16,
                hub: s.hub.info(),
                reloads: s.hub.reloads(),
                learn: s.trainer.is_some(),
            })
            .collect()
    }

    /// Per-shard statistics, in wire-id order.
    pub fn per_shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .map(|s| ShardStats {
                name: s.name.clone(),
                stats: s.hub.stats(),
                gen: s.hub.generation(),
                reloads: s.hub.reloads(),
                trainer: s.trainer.as_ref().map(OnlineTrainer::stats),
            })
            .collect()
    }

    /// Aggregate statistics across every shard.
    pub fn stats_total(&self) -> StatsSnapshot {
        let mut total = StatsSnapshot::default();
        for s in &self.shards {
            total.add(&s.hub.stats());
        }
        total
    }

    /// Total hot reloads applied across all shards.
    pub fn reloads(&self) -> u64 {
        self.shards.iter().map(|s| s.hub.reloads()).sum()
    }

    /// Shut every shard down (drain + join). Trainers go first — each
    /// drains its queue and publishes a final snapshot into a hub that
    /// is still accepting reloads — then the hubs. Returns the final
    /// aggregated statistics. Idempotent.
    pub fn shutdown(&self) -> StatsSnapshot {
        for s in &self.shards {
            if let Some(t) = &s.trainer {
                t.shutdown();
            }
        }
        let mut total = StatsSnapshot::default();
        for s in &self.shards {
            total.add(&s.hub.shutdown());
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::ModelSnapshot;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn snapshot(dim: usize, w: f64) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![w; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    fn two_shard_registry() -> ModelRegistry {
        ModelRegistry::new(
            vec![
                ("default".into(), snapshot(8, 1.0).into()),
                ("neg".into(), snapshot(16, -1.0).into()),
            ],
            4,
            64,
            1,
            0,
        )
        .unwrap()
    }

    #[test]
    fn routes_by_name_and_id_with_independent_dims() {
        let reg = two_shard_registry();
        assert_eq!(reg.len(), 2);
        let (id, hub) = reg.resolve_name(None).unwrap();
        assert_eq!(id, 0);
        assert!(hub.submit(vec![1.0; 8]).unwrap().recv().unwrap().score > 0.0);
        let (id, hub) = reg.resolve_name(Some("neg")).unwrap();
        assert_eq!(id, 1);
        assert!(hub.submit(vec![1.0; 16]).unwrap().recv().unwrap().score < 0.0);
        assert!(reg.resolve_id(1).is_ok());
        match reg.resolve_name(Some("nope")) {
            Err(RegistryError::UnknownName(name)) => assert_eq!(name, "nope"),
            other => panic!("expected unknown name, got {other:?}"),
        }
        assert_eq!(reg.resolve_id(7), Err(RegistryError::UnknownId(7)));
        reg.shutdown();
    }

    #[test]
    fn reload_touches_one_shard_only() {
        let reg = two_shard_registry();
        assert_eq!(reg.reload(Some("neg"), snapshot(16, 1.0).into()).unwrap(), 16);
        // The reloaded shard flips; the default shard's generation and
        // behavior are untouched.
        let (_, neg) = reg.resolve_name(Some("neg")).unwrap();
        assert_eq!(neg.generation(), 2);
        assert!(neg.submit(vec![1.0; 16]).unwrap().recv().unwrap().score > 0.0);
        assert_eq!(reg.default_hub().generation(), 1);
        assert_eq!(reg.reloads(), 1);
        let infos = reg.infos();
        assert_eq!(infos.len(), 2);
        assert_eq!((infos[0].id, infos[0].hub.gen, infos[0].reloads), (0, 1, 0));
        assert_eq!((infos[1].id, infos[1].hub.gen, infos[1].reloads), (1, 2, 1));
        match reg.reload(Some("ghost"), snapshot(4, 1.0).into()) {
            Err(RegistryError::UnknownName(_)) => {}
            other => panic!("expected unknown name, got {other:?}"),
        }
        reg.shutdown();
    }

    #[test]
    fn stats_aggregate_and_split_per_shard() {
        let reg = two_shard_registry();
        reg.default_hub().submit(vec![1.0; 8]).unwrap().recv().unwrap();
        let (_, neg) = reg.resolve_name(Some("neg")).unwrap();
        neg.submit(vec![1.0; 16]).unwrap().recv().unwrap();
        neg.submit(vec![-1.0; 16]).unwrap().recv().unwrap();
        assert_eq!(reg.stats_total().served, 3);
        let per = reg.per_shard_stats();
        assert_eq!(per[0].stats.served, 1);
        assert_eq!(per[1].stats.served, 2);
        assert_eq!(reg.shutdown().served, 3);
    }

    #[test]
    fn learn_routes_to_attached_trainer_and_publishes() {
        let mut reg = two_shard_registry();
        let cfg = TrainerWireConfig {
            queue: 64,
            publish_every_updates: 1, // publish on every update: observable fast
            publish_every_ms: 0,
            seed: 3,
            ..TrainerWireConfig::default()
        };
        reg.attach_trainer(None, &cfg).unwrap();
        assert!(reg.has_trainer(None));
        assert!(!reg.has_trainer(Some("neg")));
        assert!(reg.attach_trainer(None, &cfg).is_err(), "double attach");
        assert!(reg.attach_trainer(Some("ghost"), &cfg).is_err(), "unknown shard");
        let infos = reg.infos();
        assert!(infos[0].learn && !infos[1].learn);

        // Unrouted learns land on the default shard's trainer.
        let x = Features::Sparse { idx: vec![0, 3], val: vec![1.0, -1.0] };
        let (gen, seen) = reg.learn(None, x.clone(), 1.0).unwrap();
        assert!(gen >= 1);
        assert_eq!(seen, 1);
        assert_eq!(reg.learn_by_id(0, x.clone(), -1.0).unwrap().1, 2);
        // The first example updates from w = 0 and K = 1 publishes, so
        // the shard's generation must eventually move past the seed gen.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while reg.default_hub().generation() < 2 {
            assert!(std::time::Instant::now() < deadline, "trainer publish never landed");
            std::thread::yield_now();
        }

        // Routing errors: no trainer on the other shard, unknown names,
        // and the same dimension screen the score path has.
        match reg.learn(Some("neg"), x.clone(), 1.0) {
            Err(RegistryError::NoTrainer(name)) => assert_eq!(name, "neg"),
            other => panic!("expected no-trainer, got {other:?}"),
        }
        assert!(matches!(
            reg.learn(Some("ghost"), x.clone(), 1.0),
            Err(RegistryError::UnknownName(_))
        ));
        assert!(matches!(
            reg.learn_by_id(9, x.clone(), 1.0),
            Err(RegistryError::UnknownId(9))
        ));
        match reg.learn(None, Features::Sparse { idx: vec![8], val: vec![1.0] }, 1.0) {
            Err(RegistryError::Hub(HubError::DimMismatch { expected: 8, got: 9 })) => {}
            other => panic!("expected dim mismatch, got {other:?}"),
        }

        let per = reg.per_shard_stats();
        let t = per[0].trainer.expect("default shard has a trainer");
        assert_eq!(t.examples, 2);
        assert!(per[1].trainer.is_none());
        reg.shutdown();
        assert!(matches!(reg.learn(None, x, 1.0), Err(RegistryError::TrainerClosed)));
    }

    #[test]
    fn trainer_rejects_ensemble_shards() {
        use crate::coordinator::service::{EnsembleSnapshot, VoterSnapshot};
        let ensemble = EnsembleSnapshot {
            classes: vec![0, 1],
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
            voters: vec![VoterSnapshot {
                pos: 0,
                neg: 1,
                weights: vec![1.0; 8],
                var_sn: 4.0,
            }],
        };
        let mut reg =
            ModelRegistry::new(vec![("digits".into(), ensemble.into())], 4, 64, 1, 0).unwrap();
        let err = reg.attach_trainer(None, &TrainerWireConfig::default()).unwrap_err();
        assert!(err.to_string().contains("binary"), "got {err}");
        reg.shutdown();
    }

    #[test]
    fn construction_rejects_bad_shard_sets() {
        assert!(ModelRegistry::new(vec![], 4, 64, 1, 0).is_err(), "empty");
        assert!(
            ModelRegistry::new(
                vec![
                    ("a".into(), snapshot(4, 1.0).into()),
                    ("a".into(), snapshot(4, 1.0).into()),
                ],
                4,
                64,
                1,
                0
            )
            .is_err(),
            "duplicate name"
        );
        assert!(
            ModelRegistry::new(vec![(String::new(), snapshot(4, 1.0).into())], 4, 64, 1, 0)
                .is_err(),
            "empty name"
        );
    }
}
