//! [`ModelRegistry`]: a named collection of independently hot-reloadable
//! model shards behind one serving port, with **runtime add/remove**.
//!
//! Each shard is a [`ModelHub`] — it keeps the hub's generation-pinning
//! and drain-on-swap semantics — hosting either a binary model or an
//! all-pairs multiclass ensemble ([`ServingModel`]). Shards registered
//! at startup (`serve --model name=path`, repeatable) can be joined and
//! retired at runtime through [`ModelRegistry::add_model`] and
//! [`ModelRegistry::remove_model`] (the protocol v5 `add-model` /
//! `remove-model` ops) without stalling traffic on any other shard.
//!
//! # Routing: RCU over an immutable table
//!
//! Routes live in an immutable [`RouteTable`] behind an atomic pointer.
//! Readers resolve lock-free: pin an epoch parity (two counter
//! increments), deref the table, clone the shard's `Arc`, unpin. Writers
//! serialize on a mutex, clone the table, apply the change, publish the
//! new table with one pointer swap, then free the old table only after
//! every reader pinned to the retiring epoch parity has drained — a
//! grace period of microseconds, since readers only hold the pin across
//! a hash lookup. Score and learn admission never touch the writer
//! mutex, so adding or removing one shard never stalls siblings.
//!
//! Wire ids are **monotonic and never reused**: removal leaves a hole in
//! the slot vector, so a stale binary frame addressing a removed id gets
//! an `unknown-model` error instead of silently landing on a newcomer.
//!
//! # Removal ordering
//!
//! Removing a shard first unpublishes its routes (synchronous, covers
//! the grace period), then hands the shard to a background reclaim
//! thread that follows the shutdown ordering the online-learning
//! subsystem established: quiesce and join the shard's
//! [`OnlineTrainer`] first — it drains its queue and publishes a final
//! snapshot into a hub that still accepts reloads — then drain the
//! [`ModelHub`]. Admitted requests are answered even as the shard
//! drains; its counters fold into the registry totals, which never go
//! backwards. The **default shard** (wire id 0) answers un-routed
//! requests and can never be removed.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

use crate::config::{BrownoutConfig, TrainerWireConfig};
use crate::coordinator::online::{LearnError, OnlineTrainer, SnapshotStore, TrainerStatsSnapshot};
use crate::coordinator::service::{CompletionNotifier, Features, ServingModel, StatsSnapshot};
use crate::error::{Error, Result};
use crate::server::hub::{HubError, HubInfo, ModelHub};

/// Name of the shard that answers un-routed (single-model) requests
/// when none is given explicitly at registration time.
pub const DEFAULT_MODEL: &str = "default";

/// Lifecycle states, reported by the `models` op as
/// `"serving"` / `"draining"` / `"removed-pending-drain"`.
const STATE_SERVING: u8 = 0;
const STATE_DRAINING: u8 = 1;
const STATE_REMOVED_PENDING_DRAIN: u8 = 2;

/// Why the registry could not route or apply a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    /// No shard with that name.
    UnknownName(String),
    /// No shard with that wire id (never issued, or removed).
    UnknownId(u16),
    /// The shard rejected the request (shed, kind/dim mismatch, ...).
    Hub(HubError),
    /// A `learn` was routed to a shard with no online trainer attached.
    NoTrainer(String),
    /// The shard's learn queue was full; the example was shed. Retryable.
    LearnShed,
    /// The shard's trainer has shut down.
    TrainerClosed,
    /// `add-model` named a shard that already exists.
    ModelExists(String),
    /// The name is still draining from a recent removal. Retryable.
    ModelBusy(String),
    /// `remove-model` named the default shard, which cannot be removed.
    DefaultModel(String),
    /// The add/remove request was malformed (empty name, id space
    /// exhausted, trainer on an ensemble, ...).
    Invalid(String),
}

impl From<HubError> for RegistryError {
    fn from(e: HubError) -> Self {
        RegistryError::Hub(e)
    }
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::UnknownName(name) => write!(f, "unknown model {name:?}"),
            RegistryError::UnknownId(id) => write!(f, "unknown model id {id}"),
            RegistryError::Hub(e) => write!(f, "{e}"),
            RegistryError::NoTrainer(name) => {
                write!(f, "model {name:?} has no online trainer attached")
            }
            RegistryError::LearnShed => write!(f, "overloaded"),
            RegistryError::TrainerClosed => write!(f, "trainer closed"),
            RegistryError::ModelExists(name) => write!(f, "model {name:?} already exists"),
            RegistryError::ModelBusy(name) => {
                write!(f, "model {name:?} is still draining; retry shortly")
            }
            RegistryError::DefaultModel(name) => {
                write!(f, "model {name:?} is the default shard and cannot be removed")
            }
            RegistryError::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

/// One serving shard: a named, independently reloadable [`ModelHub`],
/// optionally fed by a background [`OnlineTrainer`] that publishes
/// fresh snapshot generations into the hub.
struct Shard {
    name: String,
    /// Interned wire id (monotonic; never reused after removal).
    id: u16,
    /// Shared so an attached trainer can publish into the hub's
    /// generation swap from its own thread.
    hub: Arc<ModelHub>,
    /// Set at most once (`OnceLock`, so attachment works behind the
    /// shared `Arc` without a shard-level lock on the learn path).
    trainer: OnceLock<OnlineTrainer>,
    /// Lifecycle: serving → draining → removed-pending-drain.
    state: AtomicU8,
}

impl Shard {
    /// Route one labeled example to this shard's trainer. Returns
    /// `(serving generation, cumulative accepted examples)` for the ack.
    fn learn(
        &self,
        features: Features,
        label: f64,
    ) -> std::result::Result<(u32, u64), RegistryError> {
        let trainer =
            self.trainer.get().ok_or_else(|| RegistryError::NoTrainer(self.name.clone()))?;
        // Same dimension screen the score path applies at admission: a
        // bad payload must never reach the trainer thread.
        if let Err((expected, got)) = features.check_dim(self.hub.dim()) {
            return Err(RegistryError::Hub(HubError::DimMismatch { expected, got }));
        }
        let seen = trainer.learn(features, label).map_err(|e| match e {
            LearnError::Shed => RegistryError::LearnShed,
            LearnError::Closed => RegistryError::TrainerClosed,
        })?;
        Ok((self.hub.generation(), seen))
    }

    fn state_name(&self) -> &'static str {
        match self.state.load(Ordering::Acquire) {
            STATE_SERVING => "serving",
            STATE_DRAINING => "draining",
            _ => "removed-pending-drain",
        }
    }

    fn info(&self) -> ShardInfo {
        ShardInfo {
            name: self.name.clone(),
            id: self.id,
            hub: self.hub.info(),
            reloads: self.hub.reloads(),
            learn: self.trainer.get().is_some(),
            state: self.state_name(),
        }
    }

    fn shard_stats(&self) -> ShardStats {
        ShardStats {
            name: self.name.clone(),
            stats: self.hub.stats(),
            gen: self.hub.generation(),
            reloads: self.hub.reloads(),
            trainer: self.trainer.get().map(OnlineTrainer::stats),
            state: self.state_name(),
        }
    }
}

/// A shard's identity and live serving state, as listed by the `models`
/// op.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    /// Shard name (the JSON routing key).
    pub name: String,
    /// Interned wire id (the binary-frame routing key; 0 = default).
    pub id: u16,
    /// Live serving state (generation, dim, kind, voters).
    pub hub: HubInfo,
    /// Hot reloads applied to this shard.
    pub reloads: u64,
    /// Whether an online trainer is attached (the shard accepts `learn`).
    pub learn: bool,
    /// Lifecycle state: `"serving"`, `"draining"`, or
    /// `"removed-pending-drain"`.
    pub state: &'static str,
}

/// Per-shard slice of the `stats` op.
#[derive(Debug, Clone)]
pub struct ShardStats {
    /// Shard name.
    pub name: String,
    /// This shard's aggregated service counters.
    pub stats: StatsSnapshot,
    /// Serving generation.
    pub gen: u32,
    /// Hot reloads applied.
    pub reloads: u64,
    /// Trainer counters, when an online trainer is attached.
    pub trainer: Option<TrainerStatsSnapshot>,
    /// Lifecycle state (see [`ShardInfo::state`]).
    pub state: &'static str,
}

/// The immutable routing table readers resolve against. Index = wire
/// id; a `None` slot is the hole a removed shard leaves behind.
struct RouteTable {
    slots: Vec<Option<Arc<Shard>>>,
    by_name: HashMap<String, u16>,
}

impl RouteTable {
    fn default_shard(&self) -> &Arc<Shard> {
        self.slots[0].as_ref().expect("the default shard is never removed")
    }

    fn get(&self, name: &str) -> Option<&Arc<Shard>> {
        self.by_name.get(name).and_then(|&id| self.slots[id as usize].as_ref())
    }

    fn live(&self) -> impl Iterator<Item = &Arc<Shard>> {
        self.slots.iter().flatten()
    }
}

/// Shards unpublished but still draining, plus totals folded in from
/// shards fully reclaimed — registry counters never go backwards.
#[derive(Default)]
struct Retired {
    shards: Vec<Arc<Shard>>,
    closed: StatsSnapshot,
    closed_reloads: u64,
}

/// A named collection of independently hot-reloadable model shards that
/// can be added and removed at runtime (see the module docs for the
/// RCU scheme and removal ordering).
pub struct ModelRegistry {
    /// Live routing table. Readers pin an epoch parity and deref
    /// lock-free; writers clone-and-publish and free the old table only
    /// after its readers drain.
    table: AtomicPtr<RouteTable>,
    /// Bumped by every publish; its parity selects the reader counter
    /// new pins register on.
    epoch: AtomicU64,
    /// In-flight reader counts, one per epoch parity.
    readers: [AtomicU64; 2],
    /// Serializes writers (add/remove) and exact whole-registry
    /// observations (`models` / `stats`). Never taken on the score or
    /// learn admission path.
    writer: Mutex<()>,
    retired: Arc<Mutex<Retired>>,
    /// Reclaim threads for in-flight removals, joined at shutdown.
    reclaims: Mutex<Vec<JoinHandle<()>>>,
    /// Set once shutdown begins: add/remove are rejected after.
    closed: AtomicBool,
    /// Registration counter continuing the per-shard seed-salt series
    /// past the startup shards.
    regs: AtomicU64,
    max_batch: usize,
    queue: usize,
    workers: usize,
    seed: u64,
    notifier: CompletionNotifier,
    /// Overload-brownout config, applied to every shard — startup
    /// shards and shards added at runtime alike (each hub runs its own
    /// controller against its own admission queue).
    brownout: Option<BrownoutConfig>,
    /// When set ([`Self::set_snapshot_root`]), every trainer spawned
    /// after persists published generations under
    /// `<root>/<shard-name>/` via [`SnapshotStore`].
    snapshot_root: Mutex<Option<PathBuf>>,
}

/// An epoch pin: while alive, no table loaded through
/// [`ReadGuard::table`] can be reclaimed.
struct ReadGuard<'a> {
    reg: &'a ModelRegistry,
    parity: usize,
}

impl ReadGuard<'_> {
    fn table(&self) -> &RouteTable {
        // Safe: the pin blocks reclamation of the table for as long as
        // the guard (and thus the returned borrow) lives.
        unsafe { &*self.reg.table.load(Ordering::Acquire) }
    }
}

impl Drop for ReadGuard<'_> {
    fn drop(&mut self) {
        self.reg.readers[self.parity].fetch_sub(1, Ordering::AcqRel);
    }
}

impl ModelRegistry {
    /// Build the registry, spawning one hub per `(name, model)` pair.
    /// The first entry becomes the default shard (wire id 0). Names
    /// must be unique and non-empty; at most `u16::MAX + 1` shards.
    pub fn new(
        models: Vec<(String, ServingModel)>,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
    ) -> Result<Self> {
        Self::new_with_notifier(models, max_batch, queue, workers, seed, CompletionNotifier::default())
    }

    /// [`Self::new`] with a worker-completion notifier, fired by every
    /// shard's workers after each response send (the event-loop backend
    /// uses it to wake its pollers instead of tick-polling). The
    /// notifier is retained: shards added at runtime get it too.
    pub fn new_with_notifier(
        models: Vec<(String, ServingModel)>,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
        notifier: CompletionNotifier,
    ) -> Result<Self> {
        Self::new_with_opts(models, max_batch, queue, workers, seed, notifier, None)
    }

    /// [`Self::new_with_notifier`] plus the overload-brownout config.
    /// Like the notifier, the config is retained: every shard — startup
    /// and runtime-added — gets its own brownout controller and tiered
    /// threshold tables; `None` keeps scoring bit-identical to the
    /// undegraded path.
    pub fn new_with_opts(
        models: Vec<(String, ServingModel)>,
        max_batch: usize,
        queue: usize,
        workers: usize,
        seed: u64,
        notifier: CompletionNotifier,
        brownout: Option<BrownoutConfig>,
    ) -> Result<Self> {
        if models.is_empty() {
            return Err(Error::Config("registry needs at least one model shard".into()));
        }
        if models.len() > u16::MAX as usize + 1 {
            return Err(Error::Config(format!(
                "registry holds at most {} shards, got {}",
                u16::MAX as usize + 1,
                models.len()
            )));
        }
        let mut slots = Vec::with_capacity(models.len());
        let mut by_name = HashMap::with_capacity(models.len());
        for (i, (name, model)) in models.into_iter().enumerate() {
            if name.is_empty() {
                return Err(Error::Config("model shard name must not be empty".into()));
            }
            if by_name.insert(name.clone(), i as u16).is_some() {
                return Err(Error::Config(format!("duplicate model shard name {name:?}")));
            }
            // One seed stream per shard, so co-hosted shards never share
            // a policy RNG sequence.
            let shard_seed = seed ^ (i as u64).wrapping_mul(0xA076_1D64_78BD_642F);
            slots.push(Some(Arc::new(Shard {
                name,
                id: i as u16,
                hub: Arc::new(ModelHub::new_with_opts(
                    model,
                    max_batch,
                    queue,
                    workers,
                    shard_seed,
                    notifier.clone(),
                    brownout.clone(),
                )),
                trainer: OnceLock::new(),
                state: AtomicU8::new(STATE_SERVING),
            })));
        }
        let regs = slots.len() as u64;
        Ok(Self {
            table: AtomicPtr::new(Box::into_raw(Box::new(RouteTable { slots, by_name }))),
            epoch: AtomicU64::new(0),
            readers: [AtomicU64::new(0), AtomicU64::new(0)],
            writer: Mutex::new(()),
            retired: Arc::new(Mutex::new(Retired::default())),
            reclaims: Mutex::new(Vec::new()),
            closed: AtomicBool::new(false),
            regs: AtomicU64::new(regs),
            max_batch,
            queue,
            workers,
            seed,
            notifier,
            brownout,
            snapshot_root: Mutex::new(None),
        })
    }

    /// Enable durable snapshots: trainers attached from now on persist
    /// every published generation under `<root>/<shard-name>/` with
    /// atomic writes (see [`SnapshotStore`]). Call before
    /// [`Self::attach_trainer`] / [`Self::add_model`] so startup
    /// trainers are covered.
    pub fn set_snapshot_root(&self, root: PathBuf) {
        *self.snapshot_root.lock().unwrap() = Some(root);
    }

    /// Spawn a trainer for one shard, store-backed when a snapshot root
    /// is configured. An unopenable store (permissions, read-only disk)
    /// degrades to in-memory publishing with a warning rather than
    /// refusing the attach — serving beats durability here.
    fn spawn_trainer(
        &self,
        shard_name: &str,
        hub: Arc<ModelHub>,
        cfg: &TrainerWireConfig,
        dim: usize,
    ) -> OnlineTrainer {
        let root = self.snapshot_root.lock().unwrap().clone();
        if let Some(root) = root {
            match SnapshotStore::open(root.join(shard_name)) {
                Ok(store) => return OnlineTrainer::spawn_with_store(hub, cfg, dim, store),
                Err(e) => eprintln!(
                    "warning: snapshot store for shard {shard_name:?} unavailable ({e}); \
                     training without persistence"
                ),
            }
        }
        OnlineTrainer::spawn(hub, cfg, dim)
    }

    /// Pin the current epoch parity. The retry loop closes the race
    /// with a concurrent publish: if the epoch moved between the load
    /// and the increment, the registration may be on a parity whose
    /// grace period already passed, so back out and re-pin.
    fn pin(&self) -> ReadGuard<'_> {
        loop {
            let e = self.epoch.load(Ordering::Acquire);
            let parity = (e & 1) as usize;
            self.readers[parity].fetch_add(1, Ordering::AcqRel);
            if self.epoch.load(Ordering::Acquire) == e {
                return ReadGuard { reg: self, parity };
            }
            self.readers[parity].fetch_sub(1, Ordering::AcqRel);
        }
    }

    /// Swap `new` in as the live table, wait out the grace period, and
    /// free the old table. Requires the writer lock (the guard
    /// parameter), which also makes the pre-publish table read in
    /// add/remove safe.
    fn publish(&self, _writer: &MutexGuard<'_, ()>, new: RouteTable) {
        let new_ptr = Box::into_raw(Box::new(new));
        let old_ptr = self.table.swap(new_ptr, Ordering::AcqRel);
        let old_epoch = self.epoch.fetch_add(1, Ordering::AcqRel);
        let parity = (old_epoch & 1) as usize;
        // Every reader that could hold the old table is registered on
        // the retiring parity; they resolve routes in microseconds.
        while self.readers[parity].load(Ordering::Acquire) != 0 {
            std::thread::yield_now();
        }
        drop(unsafe { Box::from_raw(old_ptr) });
    }

    /// Register a new shard at runtime (the v5 `add-model` op) and
    /// publish it to the routing table; no other shard observes the
    /// swap. With `trainer`, an [`OnlineTrainer`] is attached before
    /// the shard becomes routable, warm-started from the model's own
    /// weights. Returns `(wire id, dim)`.
    pub fn add_model(
        &self,
        name: &str,
        model: ServingModel,
        trainer: Option<&TrainerWireConfig>,
    ) -> std::result::Result<(u16, usize), RegistryError> {
        if name.is_empty() {
            return Err(RegistryError::Invalid("model shard name must not be empty".into()));
        }
        if trainer.is_some() && model.kind_name() != "binary" {
            return Err(RegistryError::Invalid(format!(
                "online trainer needs a binary shard, {name:?} would serve {}",
                model.kind_name()
            )));
        }
        let writer = self.writer.lock().unwrap();
        if self.closed.load(Ordering::Acquire) {
            return Err(RegistryError::Hub(HubError::Closed));
        }
        let table = unsafe { &*self.table.load(Ordering::Acquire) };
        if table.by_name.contains_key(name) {
            return Err(RegistryError::ModelExists(name.to_string()));
        }
        if self.retired.lock().unwrap().shards.iter().any(|s| s.name == name) {
            return Err(RegistryError::ModelBusy(name.to_string()));
        }
        if table.slots.len() > u16::MAX as usize {
            return Err(RegistryError::Invalid(format!(
                "model id space exhausted ({} ids issued)",
                table.slots.len()
            )));
        }
        let id = table.slots.len() as u16;
        let salt = self.regs.fetch_add(1, Ordering::Relaxed);
        let shard_seed = self.seed ^ salt.wrapping_mul(0xA076_1D64_78BD_642F);
        let dim = model.dim();
        let shard = Arc::new(Shard {
            name: name.to_string(),
            id,
            hub: Arc::new(ModelHub::new_with_opts(
                model,
                self.max_batch,
                self.queue,
                self.workers,
                shard_seed,
                self.notifier.clone(),
                self.brownout.clone(),
            )),
            trainer: OnceLock::new(),
            state: AtomicU8::new(STATE_SERVING),
        });
        if let Some(cfg) = trainer {
            // Before publish: the shard is not yet routable, so the
            // OnceLock set cannot race another attach.
            let t = self.spawn_trainer(&shard.name, Arc::clone(&shard.hub), cfg, dim);
            let _ = shard.trainer.set(t);
        }
        let mut slots = table.slots.clone();
        let mut by_name = table.by_name.clone();
        slots.push(Some(Arc::clone(&shard)));
        by_name.insert(shard.name.clone(), id);
        self.publish(&writer, RouteTable { slots, by_name });
        Ok((id, dim))
    }

    /// Unpublish a shard (the v5 `remove-model` op). Synchronously
    /// removes its routes — once this returns, no new request can reach
    /// the shard, and its wire id is never reissued — then drains it on
    /// a background reclaim thread: trainer first (final snapshot
    /// publish + join), then the hub. The default shard (wire id 0)
    /// cannot be removed.
    pub fn remove_model(&self, name: &str) -> std::result::Result<(), RegistryError> {
        let writer = self.writer.lock().unwrap();
        if self.closed.load(Ordering::Acquire) {
            return Err(RegistryError::Hub(HubError::Closed));
        }
        let table = unsafe { &*self.table.load(Ordering::Acquire) };
        let &id = table
            .by_name
            .get(name)
            .ok_or_else(|| RegistryError::UnknownName(name.to_string()))?;
        if id == 0 {
            return Err(RegistryError::DefaultModel(name.to_string()));
        }
        let shard = Arc::clone(table.slots[id as usize].as_ref().expect("named shard is live"));
        let mut slots = table.slots.clone();
        let mut by_name = table.by_name.clone();
        slots[id as usize] = None;
        by_name.remove(name);
        self.publish(&writer, RouteTable { slots, by_name });
        shard.state.store(STATE_DRAINING, Ordering::Release);
        let retired = Arc::clone(&self.retired);
        retired.lock().unwrap().shards.push(Arc::clone(&shard));
        // Draining joins threads and can take as long as the trainer's
        // backlog: keep it off the control path (the event-loop backend
        // dispatches ops inline on a poller thread).
        let handle = std::thread::Builder::new()
            .name(format!("reclaim-{name}"))
            .spawn(move || {
                if let Some(t) = shard.trainer.get() {
                    t.shutdown();
                }
                shard.state.store(STATE_REMOVED_PENDING_DRAIN, Ordering::Release);
                let final_stats = shard.hub.shutdown();
                let reloads = shard.hub.reloads();
                let mut r = retired.lock().unwrap();
                r.shards.retain(|s| !Arc::ptr_eq(s, &shard));
                r.closed.add(&final_stats);
                r.closed_reloads += reloads;
            })
            .expect("spawn shard reclaim thread");
        self.reclaims.lock().unwrap().push(handle);
        Ok(())
    }

    /// Attach an online trainer to one shard (`None` = the default
    /// shard): a background thread that consumes `learn` examples and
    /// periodically publishes snapshots into the shard's hub,
    /// warm-started from the shard's current weights. Fails on ensemble
    /// shards (the trainer publishes binary snapshots) and on shards
    /// that already have a trainer.
    pub fn attach_trainer(&self, name: Option<&str>, cfg: &TrainerWireConfig) -> Result<()> {
        let shard = {
            let guard = self.pin();
            let table = guard.table();
            let shard = match name {
                None => table.default_shard(),
                Some(n) => table
                    .get(n)
                    .ok_or_else(|| Error::Config(format!("unknown model shard {n:?}")))?,
            };
            Arc::clone(shard)
        };
        let info = shard.hub.info();
        if info.kind != "binary" {
            return Err(Error::Config(format!(
                "online trainer needs a binary shard, {:?} serves {}",
                shard.name, info.kind
            )));
        }
        if shard.trainer.get().is_some() {
            return Err(Error::Config(format!(
                "model shard {:?} already has a trainer",
                shard.name
            )));
        }
        let trainer = self.spawn_trainer(&shard.name, Arc::clone(&shard.hub), cfg, info.dim);
        if shard.trainer.set(trainer).is_err() {
            // Lost an attach race; the loser is dropped, which drains
            // and joins it.
            return Err(Error::Config(format!(
                "model shard {:?} already has a trainer",
                shard.name
            )));
        }
        Ok(())
    }

    /// Route one labeled example by optional shard name (`None` = the
    /// default shard). Returns `(serving generation, examples seen)`.
    pub fn learn(
        &self,
        name: Option<&str>,
        features: Features,
        label: f64,
    ) -> std::result::Result<(u32, u64), RegistryError> {
        let shard = {
            let guard = self.pin();
            let table = guard.table();
            match name {
                None => Arc::clone(table.default_shard()),
                Some(n) => Arc::clone(
                    table.get(n).ok_or_else(|| RegistryError::UnknownName(n.to_string()))?,
                ),
            }
        };
        shard.learn(features, label)
    }

    /// Route one labeled example by interned wire id (binary
    /// `LEARN_SPARSE` frames; id 0 = default shard).
    pub fn learn_by_id(
        &self,
        id: u16,
        features: Features,
        label: f64,
    ) -> std::result::Result<(u32, u64), RegistryError> {
        let shard = {
            let guard = self.pin();
            guard
                .table()
                .slots
                .get(id as usize)
                .and_then(|s| s.as_ref())
                .map(Arc::clone)
                .ok_or(RegistryError::UnknownId(id))?
        };
        shard.learn(features, label)
    }

    /// Number of live (routable) shards.
    pub fn len(&self) -> usize {
        self.pin().table().live().count()
    }

    /// True when the registry holds no shards (never, post-construction;
    /// kept for API completeness).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The default shard's hub (wire id 0).
    pub fn default_hub(&self) -> Arc<ModelHub> {
        Arc::clone(&self.pin().table().default_shard().hub)
    }

    /// Whether the shard routed by `name` has a trainer attached.
    pub fn has_trainer(&self, name: Option<&str>) -> bool {
        let guard = self.pin();
        let table = guard.table();
        match name {
            None => table.default_shard().trainer.get().is_some(),
            Some(n) => table.get(n).is_some_and(|s| s.trainer.get().is_some()),
        }
    }

    /// Route by optional name: `None` (and the default shard's own
    /// name) lands on the default shard. Returns the interned id with
    /// the hub so binary responses can be stamped. Lock-free: an epoch
    /// pin plus an `Arc` refcount bump.
    pub fn resolve_name(
        &self,
        name: Option<&str>,
    ) -> std::result::Result<(u16, Arc<ModelHub>), RegistryError> {
        let guard = self.pin();
        let table = guard.table();
        match name {
            None => {
                let s = table.default_shard();
                Ok((s.id, Arc::clone(&s.hub)))
            }
            Some(n) => {
                let s = table.get(n).ok_or_else(|| RegistryError::UnknownName(n.to_string()))?;
                Ok((s.id, Arc::clone(&s.hub)))
            }
        }
    }

    /// Route by interned wire id (binary v3 frames; id 0 = default).
    pub fn resolve_id(&self, id: u16) -> std::result::Result<Arc<ModelHub>, RegistryError> {
        let guard = self.pin();
        guard
            .table()
            .slots
            .get(id as usize)
            .and_then(|s| s.as_ref())
            .map(|s| Arc::clone(&s.hub))
            .ok_or(RegistryError::UnknownId(id))
    }

    /// Hot-swap one shard's model (`None` routes to the default shard).
    /// Only that shard's hub mutex is touched; every other shard keeps
    /// serving untouched.
    pub fn reload(
        &self,
        name: Option<&str>,
        model: ServingModel,
    ) -> std::result::Result<usize, RegistryError> {
        let (_, hub) = self.resolve_name(name)?;
        hub.reload(model).map_err(RegistryError::Hub)
    }

    /// Identity + live state of every shard — routable shards in
    /// wire-id order (state `"serving"`), then shards still draining
    /// from a removal with their lifecycle state. Taken under the
    /// writer lock so a shard mid-removal appears exactly once.
    pub fn infos(&self) -> Vec<ShardInfo> {
        let _writer = self.writer.lock().unwrap();
        let table = unsafe { &*self.table.load(Ordering::Acquire) };
        let mut infos: Vec<ShardInfo> = table.live().map(|s| s.info()).collect();
        infos.extend(self.retired.lock().unwrap().shards.iter().map(|s| s.info()));
        infos
    }

    /// Per-shard statistics: routable shards in wire-id order, then
    /// draining shards. Exact under churn (writer lock, like
    /// [`Self::infos`]).
    pub fn per_shard_stats(&self) -> Vec<ShardStats> {
        let _writer = self.writer.lock().unwrap();
        let table = unsafe { &*self.table.load(Ordering::Acquire) };
        let mut stats: Vec<ShardStats> = table.live().map(|s| s.shard_stats()).collect();
        stats.extend(self.retired.lock().unwrap().shards.iter().map(|s| s.shard_stats()));
        stats
    }

    /// Aggregate statistics across every shard, including totals folded
    /// in from removed shards — the counters never go backwards.
    pub fn stats_total(&self) -> StatsSnapshot {
        let _writer = self.writer.lock().unwrap();
        let table = unsafe { &*self.table.load(Ordering::Acquire) };
        let mut total = StatsSnapshot::default();
        for s in table.live() {
            total.add(&s.hub.stats());
        }
        let r = self.retired.lock().unwrap();
        total.add(&r.closed);
        for s in &r.shards {
            total.add(&s.hub.stats());
        }
        total
    }

    /// Total hot reloads applied across all shards, removed ones
    /// included.
    pub fn reloads(&self) -> u64 {
        let _writer = self.writer.lock().unwrap();
        let table = unsafe { &*self.table.load(Ordering::Acquire) };
        let mut n: u64 = table.live().map(|s| s.hub.reloads()).sum();
        let r = self.retired.lock().unwrap();
        n += r.closed_reloads;
        n += r.shards.iter().map(|s| s.hub.reloads()).sum::<u64>();
        n
    }

    /// Shut every shard down (drain + join). In-flight removals are
    /// joined first; then, per shard, trainers go first — each drains
    /// its queue and publishes a final snapshot into a hub that is
    /// still accepting reloads — then the hubs. Returns the final
    /// aggregated statistics. Idempotent.
    pub fn shutdown(&self) -> StatsSnapshot {
        self.closed.store(true, Ordering::Release);
        let handles = std::mem::take(&mut *self.reclaims.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        let _writer = self.writer.lock().unwrap();
        let table = unsafe { &*self.table.load(Ordering::Acquire) };
        for s in table.live() {
            if let Some(t) = s.trainer.get() {
                t.shutdown();
            }
        }
        let mut total = StatsSnapshot::default();
        for s in table.live() {
            total.add(&s.hub.shutdown());
        }
        let r = self.retired.lock().unwrap();
        total.add(&r.closed);
        for s in &r.shards {
            total.add(&s.hub.stats());
        }
        total
    }
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        self.shutdown();
        let ptr = *self.table.get_mut();
        if !ptr.is_null() {
            drop(unsafe { Box::from_raw(ptr) });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::ModelSnapshot;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn snapshot(dim: usize, w: f64) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![w; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    fn two_shard_registry() -> ModelRegistry {
        ModelRegistry::new(
            vec![
                ("default".into(), snapshot(8, 1.0).into()),
                ("neg".into(), snapshot(16, -1.0).into()),
            ],
            4,
            64,
            1,
            0,
        )
        .unwrap()
    }

    #[test]
    fn routes_by_name_and_id_with_independent_dims() {
        let reg = two_shard_registry();
        assert_eq!(reg.len(), 2);
        let (id, hub) = reg.resolve_name(None).unwrap();
        assert_eq!(id, 0);
        assert!(hub.submit(vec![1.0; 8]).unwrap().recv().unwrap().score > 0.0);
        let (id, hub) = reg.resolve_name(Some("neg")).unwrap();
        assert_eq!(id, 1);
        assert!(hub.submit(vec![1.0; 16]).unwrap().recv().unwrap().score < 0.0);
        assert!(reg.resolve_id(1).is_ok());
        match reg.resolve_name(Some("nope")) {
            Err(RegistryError::UnknownName(name)) => assert_eq!(name, "nope"),
            other => panic!("expected unknown name, got {other:?}"),
        }
        assert!(matches!(reg.resolve_id(7), Err(RegistryError::UnknownId(7))));
        reg.shutdown();
    }

    #[test]
    fn reload_touches_one_shard_only() {
        let reg = two_shard_registry();
        assert_eq!(reg.reload(Some("neg"), snapshot(16, 1.0).into()).unwrap(), 16);
        // The reloaded shard flips; the default shard's generation and
        // behavior are untouched.
        let (_, neg) = reg.resolve_name(Some("neg")).unwrap();
        assert_eq!(neg.generation(), 2);
        assert!(neg.submit(vec![1.0; 16]).unwrap().recv().unwrap().score > 0.0);
        assert_eq!(reg.default_hub().generation(), 1);
        assert_eq!(reg.reloads(), 1);
        let infos = reg.infos();
        assert_eq!(infos.len(), 2);
        assert_eq!((infos[0].id, infos[0].hub.gen, infos[0].reloads), (0, 1, 0));
        assert_eq!((infos[1].id, infos[1].hub.gen, infos[1].reloads), (1, 2, 1));
        assert!(infos.iter().all(|i| i.state == "serving"));
        match reg.reload(Some("ghost"), snapshot(4, 1.0).into()) {
            Err(RegistryError::UnknownName(_)) => {}
            other => panic!("expected unknown name, got {other:?}"),
        }
        reg.shutdown();
    }

    #[test]
    fn stats_aggregate_and_split_per_shard() {
        let reg = two_shard_registry();
        reg.default_hub().submit(vec![1.0; 8]).unwrap().recv().unwrap();
        let (_, neg) = reg.resolve_name(Some("neg")).unwrap();
        neg.submit(vec![1.0; 16]).unwrap().recv().unwrap();
        neg.submit(vec![-1.0; 16]).unwrap().recv().unwrap();
        assert_eq!(reg.stats_total().served, 3);
        let per = reg.per_shard_stats();
        assert_eq!(per[0].stats.served, 1);
        assert_eq!(per[1].stats.served, 2);
        assert_eq!(reg.shutdown().served, 3);
    }

    #[test]
    fn learn_routes_to_attached_trainer_and_publishes() {
        let reg = two_shard_registry();
        let cfg = TrainerWireConfig {
            queue: 64,
            publish_every_updates: 1, // publish on every update: observable fast
            publish_every_ms: 0,
            seed: 3,
            ..TrainerWireConfig::default()
        };
        reg.attach_trainer(None, &cfg).unwrap();
        assert!(reg.has_trainer(None));
        assert!(!reg.has_trainer(Some("neg")));
        assert!(reg.attach_trainer(None, &cfg).is_err(), "double attach");
        assert!(reg.attach_trainer(Some("ghost"), &cfg).is_err(), "unknown shard");
        let infos = reg.infos();
        assert!(infos[0].learn && !infos[1].learn);

        // Unrouted learns land on the default shard's trainer.
        let x = Features::Sparse { idx: vec![0, 3], val: vec![1.0, -1.0] };
        let (gen, seen) = reg.learn(None, x.clone(), 1.0).unwrap();
        assert!(gen >= 1);
        assert_eq!(seen, 1);
        assert_eq!(reg.learn_by_id(0, x.clone(), -1.0).unwrap().1, 2);
        // The first example updates from w = 0 and K = 1 publishes, so
        // the shard's generation must eventually move past the seed gen.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while reg.default_hub().generation() < 2 {
            assert!(std::time::Instant::now() < deadline, "trainer publish never landed");
            std::thread::yield_now();
        }

        // Routing errors: no trainer on the other shard, unknown names,
        // and the same dimension screen the score path has.
        match reg.learn(Some("neg"), x.clone(), 1.0) {
            Err(RegistryError::NoTrainer(name)) => assert_eq!(name, "neg"),
            other => panic!("expected no-trainer, got {other:?}"),
        }
        assert!(matches!(
            reg.learn(Some("ghost"), x.clone(), 1.0),
            Err(RegistryError::UnknownName(_))
        ));
        assert!(matches!(
            reg.learn_by_id(9, x.clone(), 1.0),
            Err(RegistryError::UnknownId(9))
        ));
        match reg.learn(None, Features::Sparse { idx: vec![8], val: vec![1.0] }, 1.0) {
            Err(RegistryError::Hub(HubError::DimMismatch { expected: 8, got: 9 })) => {}
            other => panic!("expected dim mismatch, got {other:?}"),
        }

        let per = reg.per_shard_stats();
        let t = per[0].trainer.expect("default shard has a trainer");
        assert_eq!(t.examples, 2);
        assert!(per[1].trainer.is_none());
        reg.shutdown();
        assert!(matches!(reg.learn(None, x, 1.0), Err(RegistryError::TrainerClosed)));
    }

    #[test]
    fn trainer_rejects_ensemble_shards() {
        use crate::coordinator::service::{EnsembleSnapshot, VoterSnapshot};
        let ensemble = EnsembleSnapshot {
            classes: vec![0, 1],
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
            voters: vec![VoterSnapshot {
                pos: 0,
                neg: 1,
                weights: vec![1.0; 8],
                var_sn: 4.0,
            }],
        };
        let reg =
            ModelRegistry::new(vec![("digits".into(), ensemble.into())], 4, 64, 1, 0).unwrap();
        let err = reg.attach_trainer(None, &TrainerWireConfig::default()).unwrap_err();
        assert!(err.to_string().contains("binary"), "got {err}");
        reg.shutdown();
    }

    #[test]
    fn construction_rejects_bad_shard_sets() {
        assert!(ModelRegistry::new(vec![], 4, 64, 1, 0).is_err(), "empty");
        assert!(
            ModelRegistry::new(
                vec![
                    ("a".into(), snapshot(4, 1.0).into()),
                    ("a".into(), snapshot(4, 1.0).into()),
                ],
                4,
                64,
                1,
                0
            )
            .is_err(),
            "duplicate name"
        );
        assert!(
            ModelRegistry::new(vec![(String::new(), snapshot(4, 1.0).into())], 4, 64, 1, 0)
                .is_err(),
            "empty name"
        );
    }

    #[test]
    fn add_and_remove_shards_at_runtime() {
        let reg = ModelRegistry::new(
            vec![("default".into(), snapshot(8, 1.0).into())],
            4,
            64,
            1,
            0,
        )
        .unwrap();
        assert_eq!(reg.len(), 1);
        let (id, dim) = reg.add_model("b", snapshot(16, -1.0).into(), None).unwrap();
        assert_eq!((id, dim), (1, 16));
        assert_eq!(reg.len(), 2);
        let (rid, hub) = reg.resolve_name(Some("b")).unwrap();
        assert_eq!(rid, 1);
        assert!(hub.submit(vec![1.0; 16]).unwrap().recv().unwrap().score < 0.0);
        match reg.add_model("b", snapshot(4, 1.0).into(), None) {
            Err(RegistryError::ModelExists(n)) => assert_eq!(n, "b"),
            other => panic!("expected model-exists, got {other:?}"),
        }
        match reg.remove_model("default") {
            Err(RegistryError::DefaultModel(_)) => {}
            other => panic!("expected default-model, got {other:?}"),
        }
        match reg.remove_model("ghost") {
            Err(RegistryError::UnknownName(_)) => {}
            other => panic!("expected unknown name, got {other:?}"),
        }
        assert!(reg.add_model("", snapshot(4, 1.0).into(), None).is_err(), "empty name");
        reg.remove_model("b").unwrap();
        assert!(matches!(reg.resolve_name(Some("b")), Err(RegistryError::UnknownName(_))));
        assert!(matches!(reg.resolve_id(1), Err(RegistryError::UnknownId(1))));
        // Ids are never reused: the next registration gets a fresh one.
        let (id, _) = reg.add_model("c", snapshot(8, 2.0).into(), None).unwrap();
        assert_eq!(id, 2);
        // The default shard served through it all.
        assert!(reg.default_hub().submit(vec![1.0; 8]).unwrap().recv().unwrap().score > 0.0);
        reg.shutdown();
        assert!(matches!(
            reg.add_model("late", snapshot(4, 1.0).into(), None),
            Err(RegistryError::Hub(HubError::Closed))
        ));
    }

    #[test]
    fn removal_quiesces_the_trainer_then_drains_the_hub() {
        let reg = ModelRegistry::new(
            vec![("default".into(), snapshot(8, 1.0).into())],
            4,
            64,
            1,
            0,
        )
        .unwrap();
        let cfg = TrainerWireConfig {
            queue: 64,
            publish_every_updates: 1,
            publish_every_ms: 0,
            seed: 3,
            ..TrainerWireConfig::default()
        };
        let (id, dim) = reg.add_model("hot", snapshot(4, 0.0).into(), Some(&cfg)).unwrap();
        assert_eq!((id, dim), (1, 4));
        assert!(reg.has_trainer(Some("hot")));
        assert!(reg.infos().iter().any(|i| i.name == "hot" && i.learn));
        let x = Features::Sparse { idx: vec![0], val: vec![1.0] };
        reg.learn(Some("hot"), x.clone(), 1.0).unwrap();
        let (_, hub) = reg.resolve_name(Some("hot")).unwrap();
        hub.submit(vec![1.0; 4]).unwrap().recv().unwrap();
        reg.remove_model("hot").unwrap();
        assert!(matches!(
            reg.learn(Some("hot"), x, 1.0),
            Err(RegistryError::UnknownName(_))
        ));
        // Reclaim joins the trainer, then the hub; once it finishes the
        // shard leaves the listing and its counters survive in the
        // totals (the trainer's accepted example scored nothing, so
        // served counts only the one submit above).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while reg.infos().len() > 1 {
            assert!(std::time::Instant::now() < deadline, "reclaim never completed");
            std::thread::yield_now();
        }
        assert_eq!(reg.stats_total().served, 1, "removed shard's stats fold into totals");
        reg.shutdown();
    }

    #[test]
    fn churn_never_disturbs_sibling_routes() {
        let reg = Arc::new(
            ModelRegistry::new(vec![("default".into(), snapshot(8, 1.0).into())], 4, 256, 2, 0)
                .unwrap(),
        );
        let stop = Arc::new(AtomicBool::new(false));
        let (r2, s2) = (Arc::clone(&reg), Arc::clone(&stop));
        let scorer = std::thread::spawn(move || {
            let mut served = 0u64;
            while !s2.load(Ordering::Relaxed) {
                let (_, hub) = r2.resolve_name(None).expect("default route must never fail");
                let rx = hub.submit(vec![1.0; 8]).expect("sibling must never shed under churn");
                assert!(rx.recv().unwrap().score > 0.0);
                served += 1;
            }
            served
        });
        for round in 0..20 {
            let name = format!("churn-{round}");
            reg.add_model(&name, snapshot(16, -1.0).into(), None).unwrap();
            let (_, hub) = reg.resolve_name(Some(&name)).unwrap();
            assert!(hub.submit(vec![1.0; 16]).unwrap().recv().unwrap().score < 0.0);
            reg.remove_model(&name).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        let served = scorer.join().unwrap();
        assert!(served > 0, "the scorer thread must have made progress");
        reg.shutdown();
    }
}
