//! Network serving subsystem: a TCP front-end for the attentive
//! prediction service.
//!
//! The paper's Sequential Thresholded Sum Test makes per-example feature
//! cost a function of input *difficulty* — which is exactly a
//! serving-latency mechanism. This module puts the early-stopped
//! predictor behind a wire so it can serve real traffic:
//!
//! * [`protocol`] — the JSON request/response wire format (one compact
//!   JSON document per line, std-only, human-debuggable with `nc`),
//!   including the v2 sparse score form (`{"idx":[...],"val":[...]}`)
//!   and the `hello` framing negotiation.
//! * [`frame`] — the protocol-v2 length-prefixed binary framing
//!   (sparse score frames at ~10 bytes/nonzero plus JSON envelope
//!   frames for control ops), negotiated per connection with
//!   transparent fallback to v1 JSON lines. See `docs/PROTOCOL.md`.
//! * [`hub`] — [`hub::ModelHub`]: the swappable model layer. Wraps
//!   [`crate::coordinator::service::PredictionService`] and supports
//!   **hot snapshot reload**: a new worker generation is spawned, the
//!   serving handle is swapped atomically, and the retired generation
//!   drains its queue to completion — no request is ever dropped.
//! * [`registry`] — [`registry::ModelRegistry`]: a named collection of
//!   independently hot-reloadable hubs behind one port. Shards host
//!   binary models or the all-pairs multiclass ensemble; routing is
//!   lock-free (immutable shard table), so a reload of one shard never
//!   stalls another. The first shard is the default, keeping v1
//!   single-model clients working unmodified.
//! * [`tcp`] — the front-end proper: accept loop, route resolution
//!   before admission, bounded-queue admission control that sheds load
//!   with an explicit `overloaded` response, and `stats`/`models`
//!   endpoints exposing throughput, features-touched histograms,
//!   early-exit rates, and per-wire/per-shard splits. Two transport
//!   backends (`ServerConfig.io_backend`): per-connection
//!   reader/writer thread pairs (default, portable) or the epoll event
//!   loop below.
//! * `event_loop` (Linux) — the scaling transport: sharded epoll loops
//!   multiplexing every connection with pooled reusable buffers, a
//!   zero-copy decode path, and backpressure expressed as epoll
//!   interest — thousands of mostly-idle connections on a handful of
//!   I/O threads, with no per-request transport allocation at steady
//!   state. See `docs/PERFORMANCE.md`.
//! * [`bufpool`] — the bounded buffer pool behind both backends'
//!   reusable connection/render buffers.
//! * [`loadgen`] — a loopback load-generator client: configurable
//!   connection count, pipelining depth, and easy/hard traffic mix, used
//!   by `attentive bench-serve`, `benches/serve_throughput.rs`, and the
//!   loopback integration test. Its [`loadgen::Client`] retries
//!   retryable refusals with exponential backoff + jitter and
//!   reconnects on connection loss.
//! * [`faultpoint`] — env/config-gated fault injection (torn writes,
//!   delayed flushes, forced worker panics, snapshot-write failure)
//!   behind `ATTENTIVE_FAULT`, driving the `tests/chaos.rs` suite; a
//!   single relaxed atomic load when disarmed.
//!
//! ## Quick tour
//!
//! ```no_run
//! use attentive::config::ServerConfig;
//! use attentive::coordinator::service::ModelSnapshot;
//! use attentive::margin::policy::CoordinatePolicy;
//! use attentive::server::tcp::TcpServer;
//! use attentive::stst::boundary::AnyBoundary;
//!
//! let snapshot = ModelSnapshot {
//!     weights: vec![1.0; 784],
//!     var_sn: 4.0,
//!     boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
//!     policy: CoordinatePolicy::Permuted,
//! };
//! let cfg = ServerConfig { listen: "127.0.0.1:0".into(), ..Default::default() };
//! let server = TcpServer::serve(&cfg, snapshot).unwrap();
//! println!("serving on {}", server.local_addr());
//! server.wait();
//! ```

pub mod bufpool;
#[cfg(target_os = "linux")]
pub(crate) mod event_loop;
pub mod faultpoint;
pub mod frame;
pub mod hub;
pub mod loadgen;
pub mod protocol;
pub mod registry;
pub mod tcp;

pub use bufpool::{BufPool, BufPoolStats};
pub use frame::{ErrorCode, Frame, FrameRef};
pub use hub::ModelHub;
pub use loadgen::{Client, ClientMode, LoadGenConfig, LoadReport};
pub use protocol::{ModelEntry, Request, Response, StatsReport};
pub use registry::{ModelRegistry, RegistryError, DEFAULT_MODEL};
pub use tcp::TcpServer;
