//! TCP front-end: accept loop, per-connection reader/writer threads,
//! bounded-queue admission, and the stats/reload control ops.
//!
//! ## Threading model
//!
//! One accept thread; per connection, a **reader** thread that parses
//! JSON-lines requests and a **writer** thread that emits responses in
//! request order. Score requests are admitted to the
//! [`ModelHub`]'s bounded queue without blocking: if the queue is full
//! the reader immediately enqueues an explicit `overloaded` error line
//! instead of buffering — load is shed at the edge, never accumulated.
//! Admitted requests travel to the writer as pending response receivers,
//! bounded by `max_pending_per_conn` (the per-connection pipelining
//! window): a slow consumer backpressures its own reader, not the whole
//! server.
//!
//! ## Control ops
//!
//! `stats` returns the aggregated [`StatsReport`] (throughput,
//! features-touched percentiles, early-exit rate, shed counts); `reload`
//! hot-swaps the serving [`ModelSnapshot`] with zero downtime (see
//! [`ModelHub`]). Both arrive over the same wire as ordinary requests, so
//! any connection can act as a control channel.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::ServerConfig;
use crate::coordinator::service::{ModelSnapshot, ScoreResponse};
use crate::error::{Error, Result};
use crate::server::hub::{HubError, ModelHub};
use crate::server::protocol::{Request, Response, StatsReport};

/// Server-wide shared state.
struct Shared {
    hub: ModelHub,
    shutting_down: AtomicBool,
    accepted: AtomicU64,
    overloaded: AtomicU64,
    protocol_errors: AtomicU64,
    started: Instant,
    /// Stream clones used to unblock connection readers at shutdown,
    /// keyed by connection id; entries are removed when the connection
    /// closes so long-lived servers don't leak fds.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_id: AtomicU64,
    conn_joins: Mutex<Vec<JoinHandle<()>>>,
    max_pending: usize,
}

/// A running TCP serving front-end.
///
/// Dropping the server shuts it down cleanly (stops accepting, closes
/// connections, drains every admitted request, joins all threads).
pub struct TcpServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept_join: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `cfg.listen` and start serving `snapshot`.
    pub fn serve(cfg: &ServerConfig, snapshot: ModelSnapshot) -> Result<TcpServer> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.listen).map_err(|e| Error::io(&cfg.listen, e))?;
        let local_addr = listener.local_addr().map_err(|e| Error::io(&cfg.listen, e))?;
        let shared = Arc::new(Shared {
            hub: ModelHub::new(snapshot, cfg.max_batch, cfg.queue, cfg.workers, cfg.seed),
            shutting_down: AtomicBool::new(false),
            accepted: AtomicU64::new(0),
            overloaded: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            started: Instant::now(),
            conns: Mutex::new(HashMap::new()),
            next_conn_id: AtomicU64::new(0),
            conn_joins: Mutex::new(Vec::new()),
            max_pending: cfg.max_pending_per_conn,
        });
        let accept_shared = shared.clone();
        let accept_join = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(TcpServer { shared, local_addr, accept_join: Some(accept_join) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Current server statistics (same payload as the `stats` op).
    pub fn stats(&self) -> StatsReport {
        report(&self.shared)
    }

    /// Programmatic hot reload (same semantics as the `reload` op).
    pub fn reload(&self, snapshot: ModelSnapshot) -> std::result::Result<usize, HubError> {
        self.shared.hub.reload(snapshot)
    }

    /// Block on the accept loop. It only exits if the listener itself
    /// fails (in normal operation the process runs until killed — there
    /// is no cross-thread stop signal once `self` is consumed; use
    /// [`Self::shutdown`] instead of `wait` when you need a programmatic
    /// stop). Cleans up if the loop ever does exit.
    pub fn wait(mut self) {
        if let Some(join) = self.accept_join.take() {
            let _ = join.join();
        }
        self.teardown_connections();
        self.shared.hub.shutdown();
    }

    /// Stop accepting, drain and answer every admitted request, join all
    /// threads, and return the final statistics.
    pub fn shutdown(mut self) -> StatsReport {
        self.shutdown_impl();
        report(&self.shared)
    }

    fn shutdown_impl(&mut self) {
        let Some(accept_join) = self.accept_join.take() else {
            return; // already shut down
        };
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so it observes the flag.
        let _ = TcpStream::connect(self.local_addr);
        let _ = accept_join.join();
        self.teardown_connections();
        self.shared.hub.shutdown();
    }

    fn teardown_connections(&self) {
        // Unblock every connection reader; EOF ends the reader, which
        // drops the job channel, which lets the writer drain and exit.
        for (_, stream) in self.shared.conns.lock().unwrap().drain() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        let joins = std::mem::take(&mut *self.shared.conn_joins.lock().unwrap());
        for join in joins {
            let _ = join.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        shared.accepted.fetch_add(1, Ordering::Relaxed);
        let conn_id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().unwrap().insert(conn_id, clone);
        }
        let conn_shared = shared.clone();
        let join = std::thread::spawn(move || {
            handle_conn(stream, &conn_shared);
            // Release this connection's shutdown clone (fd) as soon as
            // the connection ends, not at server teardown.
            conn_shared.conns.lock().unwrap().remove(&conn_id);
        });
        let mut joins = shared.conn_joins.lock().unwrap();
        // Reap handles of connections that already finished so a
        // long-running server doesn't accumulate one per connection.
        joins.retain(|j| !j.is_finished());
        joins.push(join);
    }
}

/// What the reader hands the writer, in request order.
enum Job {
    /// A fully-formed response line.
    Line(String),
    /// An admitted score request whose response is still being computed.
    Pending { id: Option<u64>, rx: Receiver<ScoreResponse> },
}

fn handle_conn(stream: TcpStream, shared: &Shared) {
    let Ok(read_half) = stream.try_clone() else { return };
    let reader = BufReader::new(read_half);
    let (jtx, jrx) = sync_channel::<Job>(shared.max_pending);
    let writer = std::thread::spawn(move || writer_loop(stream, jrx));

    for line in reader.lines() {
        let Ok(line) = line else { break };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let job = match Request::parse(line) {
            Err(e) => {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                Job::Line(Response::Error { id: None, error: e, retryable: false }.to_line())
            }
            Ok(Request::Ping) => Job::Line(Response::Pong.to_line()),
            Ok(Request::Stats) => Job::Line(Response::Stats(report(shared)).to_line()),
            Ok(Request::Reload { snapshot }) => match shared.hub.reload(snapshot) {
                Ok(dim) => Job::Line(Response::Reloaded { dim }.to_line()),
                Err(e) => Job::Line(
                    Response::Error { id: None, error: e.to_string(), retryable: false }.to_line(),
                ),
            },
            Ok(Request::Score { id, features }) => match shared.hub.submit(features) {
                Ok(rx) => Job::Pending { id, rx },
                Err(HubError::Overloaded) => {
                    shared.overloaded.fetch_add(1, Ordering::Relaxed);
                    Job::Line(
                        Response::Error { id, error: "overloaded".into(), retryable: true }
                            .to_line(),
                    )
                }
                Err(e @ HubError::DimMismatch { .. }) => Job::Line(
                    Response::Error { id, error: e.to_string(), retryable: false }.to_line(),
                ),
                Err(HubError::Closed) => break,
            },
        };
        if jtx.send(job).is_err() {
            break; // writer gone (connection dead)
        }
    }
    drop(jtx); // writer drains the remaining jobs, then exits
    let _ = writer.join();
}

fn writer_loop(stream: TcpStream, jrx: Receiver<Job>) {
    let mut out = BufWriter::new(stream);
    'outer: loop {
        let Ok(mut job) = jrx.recv() else { break };
        // Drain queued jobs before flushing, so a burst costs one syscall
        // instead of one per response — but never hold already-written
        // responses hostage to a computation that isn't done yet: flush
        // before blocking on an unready pending receiver.
        loop {
            let line = match job {
                Job::Line(line) => line,
                Job::Pending { id, rx } => match rx.try_recv() {
                    Ok(resp) => render_score(id, Some(resp)),
                    Err(TryRecvError::Empty) => {
                        if out.flush().is_err() {
                            break 'outer;
                        }
                        render_score(id, rx.recv().ok())
                    }
                    Err(TryRecvError::Disconnected) => render_score(id, None),
                },
            };
            if out.write_all(line.as_bytes()).is_err() {
                break 'outer;
            }
            match jrx.try_recv() {
                Ok(next) => job = next,
                Err(_) => break, // empty or disconnected: flush, then re-recv
            }
        }
        if out.flush().is_err() {
            break;
        }
    }
    let _ = out.flush();
}

/// Render an admitted request's outcome (`None` = the worker generation
/// died before answering, which a drained shutdown should never produce).
fn render_score(id: Option<u64>, resp: Option<ScoreResponse>) -> String {
    match resp {
        None => Response::Error { id, error: "service unavailable".into(), retryable: false }
            .to_line(),
        // NaN marks the worker-level dimension guard; the hub screens
        // dimensions at admission, so this only fires if a reload changed
        // the model dim while the request was in flight.
        Some(resp) if resp.score.is_nan() => Response::Error {
            id,
            error: "dimension mismatch (model reloaded mid-flight)".into(),
            retryable: true,
        }
        .to_line(),
        // Non-finite margins (e.g. inf weights in a reloaded snapshot)
        // cannot be serialized as JSON.
        Some(resp) if !resp.score.is_finite() => {
            Response::Error { id, error: "non-finite score".into(), retryable: false }.to_line()
        }
        Some(resp) => {
            Response::Score { id, score: resp.score, features_evaluated: resp.features_evaluated }
                .to_line()
        }
    }
}

fn report(shared: &Shared) -> StatsReport {
    let s = shared.hub.stats();
    let uptime = shared.started.elapsed().as_secs_f64().max(1e-9);
    StatsReport {
        served: s.served,
        avg_features: s.avg_features(),
        early_exit_rate: s.early_exit_rate(),
        batches: s.batches,
        features_p50: s.feature_percentile(0.50),
        features_p90: s.feature_percentile(0.90),
        features_p99: s.feature_percentile(0.99),
        accepted_conns: shared.accepted.load(Ordering::Relaxed),
        overloaded: shared.overloaded.load(Ordering::Relaxed),
        protocol_errors: shared.protocol_errors.load(Ordering::Relaxed),
        reloads: shared.hub.reloads(),
        uptime_s: uptime,
        req_per_s: s.served as f64 / uptime,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn snapshot(dim: usize) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![1.0; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    fn ephemeral_cfg() -> ServerConfig {
        ServerConfig { listen: "127.0.0.1:0".into(), ..Default::default() }
    }

    #[test]
    fn serve_and_shutdown_is_clean() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0, "ephemeral port must be resolved");
        let stats = server.shutdown();
        assert_eq!(stats.served, 0);
    }

    #[test]
    fn drop_without_explicit_shutdown_does_not_hang() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        drop(server);
    }

    #[test]
    fn programmatic_reload_counts() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        assert_eq!(server.reload(snapshot(16)).unwrap(), 16);
        assert_eq!(server.stats().reloads, 1);
        server.shutdown();
    }
}
