//! TCP front-end: accept loop, per-connection reader/writer threads,
//! bounded-queue admission, per-connection protocol negotiation, and
//! the stats/reload control ops.
//!
//! ## Threading model
//!
//! One accept thread; per connection, a **reader** thread that decodes
//! requests and a **writer** thread that emits responses in request
//! order. Score requests are admitted to the [`ModelHub`]'s bounded
//! queue without blocking: if the queue is full the reader immediately
//! enqueues an explicit `overloaded` error instead of buffering — load
//! is shed at the edge, never accumulated. Admitted requests travel to
//! the writer as pending response receivers, bounded by
//! `max_pending_per_conn` (the per-connection pipelining window): a
//! slow consumer backpressures its own reader, not the whole server.
//!
//! ## Protocol negotiation
//!
//! Every connection starts in v1 JSON-lines mode. A
//! `{"op":"hello","proto":2}` request flips it to the length-prefixed
//! binary framing of [`crate::server::frame`] — the reader switches
//! decoders after answering, and each queued job carries its own
//! rendering instructions, so the in-order response stream stays
//! consistent across the switch. Clients that never send `hello` (all
//! v1 clients) are served exactly as before.
//!
//! ## Control ops
//!
//! `stats` returns the aggregated [`StatsReport`] (throughput,
//! features-touched percentiles, early-exit rate, shed counts); `reload`
//! hot-swaps the serving [`ModelSnapshot`] with zero downtime (see
//! [`ModelHub`]). Both arrive over the same wire as ordinary requests —
//! in v2 binary mode they ride inside `JSON_REQ`/`JSON_RESP` envelope
//! frames — so any connection can act as a control channel.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::ServerConfig;
use crate::coordinator::service::{Features, ModelSnapshot, ScoreResponse};
use crate::error::{Error, Result};
use crate::server::frame::{ErrorCode, Frame, FrameError};
use crate::server::hub::{HubError, ModelHub};
use crate::server::protocol::{Request, Response, StatsReport, PROTO_V2};

/// Server-wide shared state.
struct Shared {
    hub: ModelHub,
    shutting_down: AtomicBool,
    accepted: AtomicU64,
    overloaded: AtomicU64,
    protocol_errors: AtomicU64,
    started: Instant,
    /// Stream clones used to unblock connection readers at shutdown,
    /// keyed by connection id; entries are removed when the connection
    /// closes so long-lived servers don't leak fds.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_id: AtomicU64,
    conn_joins: Mutex<Vec<JoinHandle<()>>>,
    max_pending: usize,
    max_frame_bytes: usize,
    max_nnz: usize,
}

/// A running TCP serving front-end.
///
/// Dropping the server shuts it down cleanly (stops accepting, closes
/// connections, drains every admitted request, joins all threads).
pub struct TcpServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept_join: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `cfg.listen` and start serving `snapshot`.
    pub fn serve(cfg: &ServerConfig, snapshot: ModelSnapshot) -> Result<TcpServer> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.listen).map_err(|e| Error::io(&cfg.listen, e))?;
        let local_addr = listener.local_addr().map_err(|e| Error::io(&cfg.listen, e))?;
        let shared = Arc::new(Shared {
            hub: ModelHub::new(snapshot, cfg.max_batch, cfg.queue, cfg.workers, cfg.seed),
            shutting_down: AtomicBool::new(false),
            accepted: AtomicU64::new(0),
            overloaded: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            started: Instant::now(),
            conns: Mutex::new(HashMap::new()),
            next_conn_id: AtomicU64::new(0),
            conn_joins: Mutex::new(Vec::new()),
            max_pending: cfg.max_pending_per_conn,
            max_frame_bytes: cfg.max_frame_bytes,
            max_nnz: cfg.max_nnz,
        });
        let accept_shared = shared.clone();
        let accept_join = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(TcpServer { shared, local_addr, accept_join: Some(accept_join) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Current server statistics (same payload as the `stats` op).
    pub fn stats(&self) -> StatsReport {
        report(&self.shared)
    }

    /// Programmatic hot reload (same semantics as the `reload` op).
    pub fn reload(&self, snapshot: ModelSnapshot) -> std::result::Result<usize, HubError> {
        self.shared.hub.reload(snapshot)
    }

    /// Block on the accept loop. It only exits if the listener itself
    /// fails (in normal operation the process runs until killed — there
    /// is no cross-thread stop signal once `self` is consumed; use
    /// [`Self::shutdown`] instead of `wait` when you need a programmatic
    /// stop). Cleans up if the loop ever does exit.
    pub fn wait(mut self) {
        if let Some(join) = self.accept_join.take() {
            let _ = join.join();
        }
        self.teardown_connections();
        self.shared.hub.shutdown();
    }

    /// Stop accepting, drain and answer every admitted request, join all
    /// threads, and return the final statistics.
    pub fn shutdown(mut self) -> StatsReport {
        self.shutdown_impl();
        report(&self.shared)
    }

    fn shutdown_impl(&mut self) {
        let Some(accept_join) = self.accept_join.take() else {
            return; // already shut down
        };
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so it observes the flag.
        let _ = TcpStream::connect(self.local_addr);
        let _ = accept_join.join();
        self.teardown_connections();
        self.shared.hub.shutdown();
    }

    fn teardown_connections(&self) {
        // Unblock every connection reader; EOF ends the reader, which
        // drops the job channel, which lets the writer drain and exit.
        for (_, stream) in self.shared.conns.lock().unwrap().drain() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        let joins = std::mem::take(&mut *self.shared.conn_joins.lock().unwrap());
        for join in joins {
            let _ = join.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        shared.accepted.fetch_add(1, Ordering::Relaxed);
        let conn_id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().unwrap().insert(conn_id, clone);
        }
        let conn_shared = shared.clone();
        let join = std::thread::spawn(move || {
            handle_conn(stream, &conn_shared);
            // Release this connection's shutdown clone (fd) as soon as
            // the connection ends, not at server teardown.
            conn_shared.conns.lock().unwrap().remove(&conn_id);
        });
        let mut joins = shared.conn_joins.lock().unwrap();
        // Reap handles of connections that already finished so a
        // long-running server doesn't accumulate one per connection.
        joins.retain(|j| !j.is_finished());
        joins.push(join);
    }
}

/// How a pending score's response must be rendered — decided at
/// admission time, so the writer needs no codec state of its own and
/// the v1→v2 switch stays consistent across the in-order job stream.
enum Wire {
    /// v1 JSON line, echoing the optional request id.
    V1 { id: Option<u64> },
    /// v2 binary `SCORE`/`ERROR` frame, stamped with the serving
    /// generation captured at admission.
    V2Binary { gen: u32 },
    /// v2 `JSON_RESP` envelope frame (a JSON-op request on a binary
    /// connection, e.g. a dense score through the envelope).
    V2Json { id: Option<u64> },
}

/// What the reader hands the writer, in request order.
enum Job {
    /// Fully-encoded response bytes (a JSON line or a binary frame).
    Bytes(Vec<u8>),
    /// An admitted score request whose response is still being computed.
    Pending { wire: Wire, rx: Receiver<ScoreResponse> },
}

/// Reader-side verdict for one decoded request.
enum Step {
    /// Enqueue this job and keep reading.
    Job(Job),
    /// Enqueue, then switch the connection to binary framing.
    JobThenBinary(Job),
    /// Enqueue, then close the connection (unrecoverable stream state).
    JobThenClose(Job),
    /// Close immediately.
    Close,
}

fn handle_conn(stream: TcpStream, shared: &Shared) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let (jtx, jrx) = sync_channel::<Job>(shared.max_pending);
    let writer = std::thread::spawn(move || writer_loop(stream, jrx));

    let mut binary = false;
    let mut line = String::new();
    loop {
        let step = if binary {
            read_binary_step(&mut reader, shared)
        } else {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => Step::Close,
                Ok(_) => {
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        continue;
                    }
                    json_step(trimmed, shared)
                }
            }
        };
        match step {
            Step::Job(job) => {
                if jtx.send(job).is_err() {
                    break; // writer gone (connection dead)
                }
            }
            Step::JobThenBinary(job) => {
                if jtx.send(job).is_err() {
                    break;
                }
                binary = true;
            }
            Step::JobThenClose(job) => {
                let _ = jtx.send(job);
                break;
            }
            Step::Close => break,
        }
    }
    drop(jtx); // writer drains the remaining jobs, then exits
    let _ = writer.join();
}

/// Handle one v1 JSON line.
fn json_step(line: &str, shared: &Shared) -> Step {
    match Request::parse(line) {
        Err(e) => {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            Step::Job(Job::Bytes(
                Response::Error { id: None, error: e, retryable: false }.to_line().into_bytes(),
            ))
        }
        Ok(Request::Hello { proto }) => {
            // Grant the highest version both sides speak; v1 keeps the
            // connection on JSON lines (transparent fallback).
            let granted = if proto >= PROTO_V2 { PROTO_V2 } else { 1 };
            // One snapshot: (gen, dim) must not tear across a reload.
            let (gen, dim) = shared.hub.serving_info();
            let resp = Response::Hello { proto: granted, gen, dim };
            let job = Job::Bytes(resp.to_line().into_bytes());
            if granted == PROTO_V2 {
                Step::JobThenBinary(job)
            } else {
                Step::Job(job)
            }
        }
        Ok(req) => json_request_step(req, shared, /* enveloped= */ false),
    }
}

/// Handle a JSON-op request arriving either as a bare v1 line
/// (`enveloped = false`) or inside a v2 `JSON_REQ` frame (`true`); the
/// response rides the matching vehicle.
fn json_request_step(req: Request, shared: &Shared, enveloped: bool) -> Step {
    let render = |resp: Response| -> Job {
        if enveloped {
            Job::Bytes(Frame::JsonResp(resp.to_json().to_string_compact()).encode())
        } else {
            Job::Bytes(resp.to_line().into_bytes())
        }
    };
    match req {
        Request::Hello { .. } => {
            // Renegotiation inside a binary connection is not a thing;
            // as a bare v1 line it is handled by `json_step`.
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            Step::Job(render(Response::Error {
                id: None,
                error: "hello: already negotiated".into(),
                retryable: false,
            }))
        }
        Request::Ping => Step::Job(render(Response::Pong)),
        Request::Stats => Step::Job(render(Response::Stats(report(shared)))),
        Request::Reload { snapshot } => match shared.hub.reload(snapshot) {
            Ok(dim) => Step::Job(render(Response::Reloaded { dim })),
            Err(e) => Step::Job(render(Response::Error {
                id: None,
                error: e.to_string(),
                retryable: false,
            })),
        },
        Request::Score { id, features } => match shared.hub.submit(features) {
            Ok(rx) => {
                let wire = if enveloped { Wire::V2Json { id } } else { Wire::V1 { id } };
                Step::Job(Job::Pending { wire, rx })
            }
            Err(HubError::Overloaded) => {
                shared.overloaded.fetch_add(1, Ordering::Relaxed);
                Step::Job(render(Response::Error {
                    id,
                    error: "overloaded".into(),
                    retryable: true,
                }))
            }
            // StaleGeneration cannot happen on an unpinned submit; fold
            // it with DimMismatch for exhaustiveness.
            Err(e @ (HubError::DimMismatch { .. } | HubError::StaleGeneration { .. })) => {
                Step::Job(render(Response::Error {
                    id,
                    error: e.to_string(),
                    retryable: false,
                }))
            }
            Err(HubError::Closed) => Step::Close,
        },
    }
}

/// Read and handle one v2 binary frame.
fn read_binary_step(reader: &mut BufReader<TcpStream>, shared: &Shared) -> Step {
    let frame = match Frame::read_from(reader, shared.max_frame_bytes) {
        Ok(frame) => frame,
        Err(FrameError::Eof) => return Step::Close,
        Err(e) => {
            // Framing is lost — a byte stream cannot resync after a bad
            // prefix. Report once, then close.
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            return Step::JobThenClose(Job::Bytes(
                Frame::Error {
                    code: ErrorCode::BadFrame,
                    retryable: false,
                    msg: e.to_string(),
                }
                .encode(),
            ));
        }
    };
    let err = |code: ErrorCode, msg: String| -> Step {
        Step::Job(Job::Bytes(
            Frame::Error { code, retryable: code.retryable(), msg }.encode(),
        ))
    };
    match frame {
        Frame::JsonReq(doc) => match Request::parse(doc.trim()) {
            Err(e) => {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                err(ErrorCode::BadRequest, e)
            }
            Ok(req) => json_request_step(req, shared, /* enveloped= */ true),
        },
        Frame::ScoreSparse { gen, idx, val } => {
            if idx.len() > shared.max_nnz {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                return err(
                    ErrorCode::BadRequest,
                    format!("nnz {} exceeds server cap {}", idx.len(), shared.max_nnz),
                );
            }
            let features = Features::Sparse {
                idx: idx.into_iter().map(u32::from).collect(),
                val,
            };
            if let Err(e) = features.validate() {
                shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let code = if e.contains("non-finite") {
                    ErrorCode::NonFinite
                } else {
                    ErrorCode::BadRequest
                };
                return err(code, e);
            }
            // The pin check, admission, and generation stamp all happen
            // under one hub critical section: the stamped generation is
            // the one whose workers answer, even across a racing reload.
            match shared.hub.submit_pinned(features, gen) {
                Ok((rx, serving)) => {
                    Step::Job(Job::Pending { wire: Wire::V2Binary { gen: serving }, rx })
                }
                Err(e @ HubError::StaleGeneration { .. }) => {
                    err(ErrorCode::StaleGeneration, e.to_string())
                }
                Err(HubError::Overloaded) => {
                    shared.overloaded.fetch_add(1, Ordering::Relaxed);
                    err(ErrorCode::Overloaded, "overloaded".into())
                }
                Err(e @ HubError::DimMismatch { .. }) => {
                    err(ErrorCode::DimMismatch, e.to_string())
                }
                Err(HubError::Closed) => Step::Close,
            }
        }
        // Response ops arriving from a client are protocol abuse.
        Frame::Score { .. } | Frame::Error { .. } | Frame::JsonResp(_) => {
            shared.protocol_errors.fetch_add(1, Ordering::Relaxed);
            err(ErrorCode::BadRequest, "response op sent by client".into())
        }
    }
}

fn writer_loop(stream: TcpStream, jrx: Receiver<Job>) {
    let mut out = BufWriter::new(stream);
    'outer: loop {
        let Ok(mut job) = jrx.recv() else { break };
        // Drain queued jobs before flushing, so a burst costs one syscall
        // instead of one per response — but never hold already-written
        // responses hostage to a computation that isn't done yet: flush
        // before blocking on an unready pending receiver.
        loop {
            let bytes = match job {
                Job::Bytes(bytes) => bytes,
                Job::Pending { wire, rx } => match rx.try_recv() {
                    Ok(resp) => render_score(&wire, Some(resp)),
                    Err(TryRecvError::Empty) => {
                        if out.flush().is_err() {
                            break 'outer;
                        }
                        render_score(&wire, rx.recv().ok())
                    }
                    Err(TryRecvError::Disconnected) => render_score(&wire, None),
                },
            };
            if out.write_all(&bytes).is_err() {
                break 'outer;
            }
            match jrx.try_recv() {
                Ok(next) => job = next,
                Err(_) => break, // empty or disconnected: flush, then re-recv
            }
        }
        if out.flush().is_err() {
            break;
        }
    }
    let _ = out.flush();
}

/// Render an admitted request's outcome on its negotiated wire (`None`
/// = the worker generation died before answering, which a drained
/// shutdown should never produce).
fn render_score(wire: &Wire, resp: Option<ScoreResponse>) -> Vec<u8> {
    // Classify once; the codes map onto the v1 error strings.
    let outcome: std::result::Result<ScoreResponse, (ErrorCode, bool, &'static str)> = match resp
    {
        None => Err((ErrorCode::Unavailable, false, "service unavailable")),
        // NaN marks the worker-level dimension guard; the hub screens
        // dimensions at admission, so this only fires if a reload changed
        // the model dim while the request was in flight.
        Some(resp) if resp.score.is_nan() => Err((
            ErrorCode::DimMismatch,
            true,
            "dimension mismatch (model reloaded mid-flight)",
        )),
        // Non-finite margins (e.g. inf weights in a reloaded snapshot)
        // cannot be serialized as JSON and are rejected on the binary
        // wire for parity.
        Some(resp) if !resp.score.is_finite() => {
            Err((ErrorCode::NonFinite, false, "non-finite score"))
        }
        Some(resp) => Ok(resp),
    };
    match wire {
        Wire::V1 { id } | Wire::V2Json { id } => {
            let resp = match outcome {
                Ok(r) => Response::Score {
                    id: *id,
                    score: r.score,
                    features_evaluated: r.features_evaluated,
                },
                Err((_, retryable, msg)) => {
                    Response::Error { id: *id, error: msg.into(), retryable }
                }
            };
            match wire {
                Wire::V2Json { .. } => {
                    Frame::JsonResp(resp.to_json().to_string_compact()).encode()
                }
                _ => resp.to_line().into_bytes(),
            }
        }
        Wire::V2Binary { gen } => match outcome {
            Ok(r) => Frame::Score {
                gen: *gen,
                evaluated: r.features_evaluated as u32,
                score: r.score,
            }
            .encode(),
            Err((code, retryable, msg)) => {
                Frame::Error { code, retryable, msg: msg.into() }.encode()
            }
        },
    }
}

fn report(shared: &Shared) -> StatsReport {
    let s = shared.hub.stats();
    let uptime = shared.started.elapsed().as_secs_f64().max(1e-9);
    StatsReport {
        served: s.served,
        avg_features: s.avg_features(),
        early_exit_rate: s.early_exit_rate(),
        batches: s.batches,
        features_p50: s.feature_percentile(0.50),
        features_p90: s.feature_percentile(0.90),
        features_p99: s.feature_percentile(0.99),
        accepted_conns: shared.accepted.load(Ordering::Relaxed),
        overloaded: shared.overloaded.load(Ordering::Relaxed),
        protocol_errors: shared.protocol_errors.load(Ordering::Relaxed),
        reloads: shared.hub.reloads(),
        uptime_s: uptime,
        req_per_s: s.served as f64 / uptime,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::margin::policy::CoordinatePolicy;
    use crate::stst::boundary::AnyBoundary;

    fn snapshot(dim: usize) -> ModelSnapshot {
        ModelSnapshot {
            weights: vec![1.0; dim],
            var_sn: 4.0,
            boundary: AnyBoundary::Constant { delta: 0.1, paper_literal: false },
            policy: CoordinatePolicy::Sequential,
        }
    }

    fn ephemeral_cfg() -> ServerConfig {
        ServerConfig { listen: "127.0.0.1:0".into(), ..Default::default() }
    }

    #[test]
    fn serve_and_shutdown_is_clean() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0, "ephemeral port must be resolved");
        let stats = server.shutdown();
        assert_eq!(stats.served, 0);
    }

    #[test]
    fn drop_without_explicit_shutdown_does_not_hang() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        drop(server);
    }

    #[test]
    fn programmatic_reload_counts() {
        let server = TcpServer::serve(&ephemeral_cfg(), snapshot(8)).unwrap();
        assert_eq!(server.reload(snapshot(16)).unwrap(), 16);
        assert_eq!(server.stats().reloads, 1);
        server.shutdown();
    }
}
